"""Serving launcher: continuous batching over the graph-native executors.

The default path runs the Ripple serving stack end to end — prefill and
batched greedy decode are Ripple graphs (``launch/steps.py``), the KV
cache is a layout-polymorphic RecordArray state tensor, and the
continuous-batching front end (``runtime/batcher.py``) admits requests
into the decode executor's fixed batch slots.  Encoder-decoder and VLM
archs fall back to the legacy jit loop automatically.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --prompt-len 16 --gen 16

``--smoke`` hard-asserts the PR-6 acceptance criteria: the graph-native
argmax token sequences are identical to the legacy jit path, the steady
decode loop traced exactly once, and a freshly constructed worker
(new Batcher + Executors from the same cfg/params) serves with ZERO new
traces, straight from the process-wide executable cache.

``--chaos`` (with ``--smoke``) re-serves the same prompts under a
deterministic fault schedule (``repro.runtime.faults``): mid-decode
step failures, an admission-scatter failure, and a device-region fault
inside the decode executor — asserting the Batcher's request-log
replay recovers with argmax-identical token streams and that a fresh
worker afterwards still serves with zero new traces.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.launch import steps as S
from repro.models.lm import init_lm


def legacy_generate(cfg, params, batch, gen: int, max_seq: int):
    """The pre-Ripple jit loop: prefill + uniform batched greedy decode.
    -> (B, gen) token matrix."""
    from repro.models.blocks import ShardCtx
    from repro.models.lm import prefill as prefill_raw

    decode_fn = jax.jit(S.make_decode_step(cfg, None), donate_argnums=1)
    t0 = time.perf_counter()
    logits, caches = jax.jit(
        lambda p, b: prefill_raw(p, b, cfg, ShardCtx(), max_seq=max_seq)
    )(params, batch)
    t_prefill = time.perf_counter() - t0
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out_tokens = [np.asarray(toks)]
    t1 = time.perf_counter()
    for _ in range(gen - 1):
        logits, caches = decode_fn(params, caches, toks)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t1
    return np.stack(out_tokens, axis=1), t_prefill, t_decode


def serve_legacy(cfg, params, args):
    rng = np.random.default_rng(0)
    B = args.batch
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (B, args.prompt_len)).astype(np.int32))}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (B, S.ENC_LEN_SERVE, cfg.frontend_dim)).astype(np.float32))
    elif cfg.frontend_dim:
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32))
    max_seq = args.prompt_len + args.gen + (
        cfg.frontend_tokens if cfg.frontend_dim and not cfg.is_encdec else 0)
    gen, t_prefill, t_decode = legacy_generate(cfg, params, batch,
                                               args.gen, max_seq)
    print(f"[serve] arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen} path=legacy")
    print(f"[serve] prefill {t_prefill*1e3:.0f}ms; decode "
          f"{t_decode/max(args.gen-1,1)*1e3:.1f}ms/tok "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print(f"[serve] sample generations (first 3 rows):\n{gen[:3]}")
    return gen


def serve_ripple(cfg, params, args):
    from repro.runtime.batcher import Batcher

    rng = np.random.default_rng(0)
    B = args.batch
    max_seq = args.prompt_len + args.gen
    prompts = rng.integers(0, cfg.vocab_size,
                           (B, args.prompt_len)).astype(np.int32)

    t0 = time.perf_counter()
    batcher = Batcher(cfg, params, batch=B, max_seq=max_seq)
    reqs = [batcher.submit(p, max_new_tokens=args.gen) for p in prompts]
    batcher.run()
    t_total = time.perf_counter() - t0
    gen = np.stack([r.generated for r in reqs])
    stats = batcher.cache_stats()
    n_tok = int(sum(len(r.generated) for r in reqs))
    print(f"[serve] arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen} path=ripple")
    print(f"[serve] {batcher.steps} decode steps, {n_tok} tokens in "
          f"{t_total*1e3:.0f}ms ({n_tok/max(t_total,1e-9):.1f} tok/s); "
          f"decode traces={stats['decode']['trace_events']}")
    print(f"[serve] sample generations (first 3 rows):\n{gen[:3]}")

    if args.smoke:
        # 1. graph-native decode == legacy jit path, token for token
        legacy, _, _ = legacy_generate(
            cfg, params, {"tokens": jnp.asarray(prompts)}, args.gen,
            max_seq)
        assert (gen == legacy).all(), (
            f"ripple/legacy argmax mismatch:\n{gen}\nvs\n{legacy}")
        print("[smoke] ripple == legacy argmax sequences  OK")

        # 2. the steady decode loop traced exactly once
        assert stats["decode"]["trace_events"] == 1, stats["decode"]
        print("[smoke] decode traced once across "
              f"{batcher.steps} steps  OK")

        # 3. a freshly constructed worker serves with ZERO new traces
        before = batcher.executor.cache_stats()["trace_events"]
        worker = Batcher(cfg, params, batch=B, max_seq=max_seq)
        wreqs = [worker.submit(p, max_new_tokens=args.gen)
                 for p in prompts]
        worker.run()
        wgen = np.stack([r.generated for r in wreqs])
        after = worker.executor.cache_stats()["trace_events"]
        assert worker.executor.plan.signature == \
            batcher.executor.plan.signature
        assert after == before, (
            f"fresh worker retraced: {before} -> {after}")
        assert (wgen == gen).all()
        print("[smoke] fresh worker served with 0 new traces  OK")

    if getattr(args, "chaos", False):
        gen = _chaos_smoke(cfg, params, args, prompts, gen, max_seq)
    return gen


def _chaos_smoke(cfg, params, args, prompts, want, max_seq):
    """Faulted serve smoke: re-serve the same prompts under a
    deterministic mid-decode fault schedule (decode-step failures, an
    admission failure, and a device-region fault inside the decode
    executor) and hard-assert the Batcher's request-log replay produced
    argmax-identical token streams — plus a FRESH worker after the
    chaos run still serves with zero new traces."""
    from repro.runtime.batcher import Batcher
    from repro.runtime.faults import Fault, FaultPlan, fault_scope

    plan = FaultPlan([
        Fault("batcher.step", step=2, times=2),     # two mid-decode faults
        Fault("batcher.admit", step=0),             # admission scatter fault
        Fault("executor.region", nth=8),            # inside the decode exec
    ])
    batcher = Batcher(cfg, params, batch=args.batch, max_seq=max_seq,
                      log=lambda *_: None)
    reqs = [batcher.submit(p, max_new_tokens=args.gen) for p in prompts]
    with fault_scope(plan):
        batcher.run()
    gen = np.stack([r.generated for r in reqs])
    assert plan.exhausted(), f"not every fault fired:\n{plan.report()}"
    assert batcher.failures >= 3, batcher.failures
    assert (gen == want).all(), (
        f"faulted ripple argmax mismatch:\n{gen}\nvs\n{want}")
    print(f"[chaos] {batcher.failures} injected failures recovered; "
          f"token streams identical  OK")

    # post-chaos: a fresh worker (same cfg/params) still serves from the
    # process-wide executable cache with zero new traces
    before = batcher.executor.cache_stats()["trace_events"]
    worker = Batcher(cfg, params, batch=args.batch, max_seq=max_seq)
    wreqs = [worker.submit(p, max_new_tokens=args.gen) for p in prompts]
    worker.run()
    wgen = np.stack([r.generated for r in wreqs])
    after = worker.executor.cache_stats()["trace_events"]
    assert after == before, f"post-chaos worker retraced: {before}->{after}"
    assert (wgen == want).all()
    print("[chaos] fresh worker after chaos: 0 new traces  OK")
    return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--legacy", action="store_true",
                    help="force the pre-Ripple jit loop")
    ap.add_argument("--chaos", action="store_true",
                    help="re-serve under a deterministic fault schedule "
                         "and assert replay-log recovery (ripple path)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0), tp=1)
    if args.legacy or cfg.is_encdec or cfg.frontend_dim:
        return serve_legacy(cfg, params, args)
    return serve_ripple(cfg, params, args)


if __name__ == "__main__":
    main()
