"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any
device initialization).

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips
The "pod" axis is an outer data-parallel axis crossing the DCN; "data" is
in-pod DP; "model" is the TP/EP/sequence-flash-decode axis on ICI.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh_auto


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_mesh(shape, axes):
    """Small-mesh helper (tests / examples) with Auto axis types
    (version-guarded: older JAX lacks ``axis_types``)."""
    return make_mesh_auto(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)
