"""Error-feedback int8 gradient compression for the DP all-reduce.

At 1000+-node scale the cross-pod (DCN) gradient all-reduce dominates the
step for small per-pod batches; int8 quantization cuts those bytes 4x
(bf16) / 4x (f32->int8+scale).  We use per-tensor max-abs scaling with an
error-feedback accumulator (Seide et al. 2014; Karimireddy et al. 2019):
the quantization residual is added back into the next step's gradient, so
the *accumulated* update is unbiased and convergence matches uncompressed
SGD/Adam to first order.

``compressed_psum`` runs inside shard_map over the DP axes: quantize ->
psum the int8 payload widened to int32 (exact integer summation — the sum
of n int8 values fits int32 for n < 2^23) -> dequantize with the psum'd
per-shard scales.  The collective payload is 1 byte/grad + one f32 scale
per tensor instead of 4 bytes/grad.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Pytree = Any


@dataclass(frozen=True)
class ErrorFeedbackState:
    residual: Pytree  # same structure/shapes as grads, f32

    @classmethod
    def init(cls, grads_shape: Pytree) -> "ErrorFeedbackState":
        return cls(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape))


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (q int8, scale f32 scalar); x_hat = q * scale."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Pytree, axis_name, *,
                    ef: ErrorFeedbackState) -> tuple[Pytree, ErrorFeedbackState]:
    """Mean-reduce ``grads`` over ``axis_name`` with int8 payloads and
    error feedback.  Must run inside shard_map; returns (mean_grads, ef')."""
    n = lax.psum(1, axis_name)

    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g)
        g_hat = dequantize_int8(q, scale)
        new_r = g - g_hat                          # local residual
        # exact integer sum of payloads; scales may differ per shard, so
        # sum q*scale via per-shard scale broadcast: psum(q * scale) ==
        # psum over f32 would defeat the byte saving, so we psum the int32
        # payload and the scales separately and correct with the max scale.
        smax = lax.pmax(scale, axis_name)
        # requantize against the shared scale (cheap, local):
        q2 = jnp.clip(jnp.round(g / smax), -127, 127).astype(jnp.int8)
        g_hat2 = q2.astype(jnp.float32) * smax
        new_r = g - g_hat2
        total = lax.psum(q2.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * (smax / n), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    mean = jax.tree.unflatten(treedef, [o[0] for o in outs])
    resid = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return mean, ErrorFeedbackState(resid)
