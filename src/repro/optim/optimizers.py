"""AdamW and Adafactor, pytree-native, with ZeRO-1 sharding of moments.

Both optimizers are (init, update, state_pspecs) triples over arbitrary
param pytrees.  ``state_pspecs`` derives the moment sharding: each moment
inherits its param's TP spec *plus* the DP axes on the first dimension
that divides — the auto-SPMD form of ZeRO-1 (the update reads grads
reduce-scattered to the moment sharding and writes params back via
all-gather, both inserted by the partitioner from the specs alone;
DESIGN.md §5).

Adafactor (factored second moment, no first moment) is the required
optimizer for arctic-480b: full Adam moments for 477B params exceed
16 GB/chip even sharded over all 256 chips (2 x 4 bytes x 477e9 / 256
= 14.9 GB); the factored estimate is ~(rows+cols) floats per matrix.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Pytree = Any


def clip_by_global_norm(grads: Pytree, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def zero1_pspec(param_pspec: P, shape: tuple[int, ...], mesh: Mesh,
                dp_axes: tuple[str, ...]) -> P:
    """Extend a param's PartitionSpec with DP axes on the first free,
    divisible dim — optimizer state sharded over data parallelism."""
    dp = tuple(a for a in dp_axes if a in mesh.shape and mesh.shape[a] > 1)
    if not dp:
        return param_pspec
    n = math.prod(mesh.shape[a] for a in dp)
    entries = list(param_pspec) + [None] * (len(shape) - len(param_pspec))
    # a mesh axis may appear at most once in a spec: drop DP axes already
    # used by the param itself (e.g. MoE experts sharded over "data")
    used = {e for ent in entries if ent is not None
            for e in (ent if isinstance(ent, tuple) else (ent,))}
    if used & set(dp):
        dp = tuple(a for a in dp if a not in used)
        if not dp:
            return param_pspec
        n = math.prod(mesh.shape[a] for a in dp)
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % n == 0 and s > 0:
            entries[i] = dp if len(dp) > 1 else dp[0]
            return P(*entries)
    return P(*entries)  # nothing divisible: stay with the param spec


def _truncate(pspec: P, ndim: int) -> P:
    entries = list(pspec)[:ndim]
    entries += [None] * (ndim - len(entries))
    return P(*entries)


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    # update(grads, state, params, step) -> (new_params, new_state)
    update: Callable[[Pytree, Pytree, Pytree, jax.Array], tuple[Pytree, Pytree]]
    # state_pspecs(param_shapes, param_pspecs, mesh, dp_axes, zero1) -> tree
    state_pspecs: Callable[..., Pytree]


def AdamW(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,  # noqa: N802
          eps: float = 1e-8, weight_decay: float = 0.1,
          moment_dtype=jnp.float32) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
            step_ = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
            step_ = step_ + weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr_t * step_
            return (newp.astype(p.dtype), m32.astype(moment_dtype),
                    v32.astype(moment_dtype))

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        outs = [upd(g, m, v, p) for g, m, v, p
                in zip(flat_g, flat_m, flat_v, flat_p)]
        newp = jax.tree.unflatten(treedef, [o[0] for o in outs])
        newm = jax.tree.unflatten(treedef, [o[1] for o in outs])
        newv = jax.tree.unflatten(treedef, [o[2] for o in outs])
        return newp, {"m": newm, "v": newv}

    def state_pspecs(param_shapes, param_pspecs, mesh, dp_axes, zero1=True):
        def one(shape_leaf, pspec):
            ps = _truncate(pspec, len(shape_leaf.shape))
            if zero1:
                ps = zero1_pspec(ps, shape_leaf.shape, mesh, dp_axes)
            return ps
        tree = jax.tree.map(one, param_shapes, param_pspecs)
        return {"m": tree, "v": tree}

    return Optimizer(init, update, state_pspecs)


def Adafactor(lr: Callable | float, *, eps: float = 1e-30,  # noqa: N802
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018), no first
    moment: state per matrix = row + col accumulators."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-0.8)
        lr_t = lr_fn(step)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = g * rfac[..., None] * cfac[..., None, :]
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr_t * (
                u + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), ns

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state)
        flat_p = treedef.flatten_up_to(params)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        newp = jax.tree.unflatten(treedef, [o[0] for o in outs])
        news = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return newp, news

    def state_pspecs(param_shapes, param_pspecs, mesh, dp_axes, zero1=True):
        def one(shape_leaf, pspec):
            shape = shape_leaf.shape
            full = list(_truncate(pspec, len(shape)))
            if _factored(shape):
                vr = P(*full[:-1])
                vc = P(*(full[:-2] + full[-1:]))
                if zero1:
                    vr = zero1_pspec(vr, shape[:-1], mesh, dp_axes)
                    vc = zero1_pspec(vc, shape[:-2] + shape[-1:], mesh,
                                     dp_axes)
                return {"vr": vr, "vc": vc}
            v = P(*full)
            if zero1:
                v = zero1_pspec(v, shape, mesh, dp_axes)
            return {"v": v}
        return jax.tree.map(one, param_shapes, param_pspecs)

    return Optimizer(init, update, state_pspecs)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return AdamW(lr, **kw)
    if name == "adafactor":
        return Adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
