"""Optimizers and distributed-optimization tricks."""

from .optimizers import (AdamW, Adafactor, Optimizer, clip_by_global_norm,
                         make_optimizer)
from .schedules import cosine_schedule, linear_warmup
from .compression import (ErrorFeedbackState, compressed_psum,
                          dequantize_int8, quantize_int8)

__all__ = [
    "AdamW", "Adafactor", "Optimizer", "clip_by_global_norm",
    "make_optimizer", "cosine_schedule", "linear_warmup",
    "ErrorFeedbackState", "compressed_psum", "quantize_int8",
    "dequantize_int8",
]
