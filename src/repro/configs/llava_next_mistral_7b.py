"""llava-next-mistral-7b [vlm] — Mistral-7B backbone + anyres vision stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The anyres tiling frontend is a STUB per the brief: ``input_specs`` supplies
precomputed patch embeddings (CLIP ViT-L/14 hidden size 1024); the backbone
projects them with the multimodal projector and prepends them to the text
sequence.  ``long_500k`` is SKIPPED: pure full attention (DESIGN.md §6).
"""

from repro.models.config import ModelConfig

FRONTEND_TOKENS = 2048  # anyres tiles (stub): image positions per sample


def config() -> ModelConfig:
    return ModelConfig(
        name="llava_next_mistral_7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        rope_base=1_000_000.0,       # mistral-7b-instruct-v0.2
        mlp_kind="swiglu",
        act="silu",
        tie_embeddings=False,
        frontend_dim=1024,           # CLIP ViT-L/14 hidden
        frontend_tokens=FRONTEND_TOKENS,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, frontend_dim=32, frontend_tokens=8,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="chunked", q_chunk=16, k_chunk=16, remat="none")
