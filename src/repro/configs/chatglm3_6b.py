"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, 2d (half-dim, interleaved) RoPE  [arXiv:2406.12793].

RoPE rotates only the first half of each head dim with interleaved pairing
(``rope_fraction=0.5, rope_mode="interleaved"``).  kv=2 heads are
replicated under 16-way TP (not divisible).  ``long_500k`` SKIPPED.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3_6b",
        family="dense",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65024,
        rope_base=10_000.0,
        rope_fraction=0.5,
        rope_mode="interleaved",
        qkv_bias=True,
        norm_eps=1e-5,
        mlp_kind="swiglu",
        act="silu",
        tie_embeddings=False,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="chunked", q_chunk=16, k_chunk=16, remat="none")
