"""Assigned-architecture registry: ``get(name)`` -> full ModelConfig,
``get_smoke(name)`` -> reduced same-family config for CPU smoke tests.

Every module defines ``config()`` (exact published config from the
assignment table) and ``smoke()`` (small layers/width/experts, same layer
pattern and feature flags, runnable on one CPU device).
"""

from __future__ import annotations

import importlib

from repro.models.config import (ALL_SHAPES, SHAPES, ModelConfig, ShapeCfg,
                                 shapes_for)

ARCH_IDS = (
    "llava_next_mistral_7b",
    "seamless_m4t_medium",
    "qwen1_5_4b",
    "chatglm3_6b",
    "qwen3_8b",
    "gemma3_12b",
    "mamba2_130m",
    "arctic_480b",
    "phi3_5_moe",
    "recurrentgemma_9b",
)

# CLI aliases (the assignment's hyphenated ids)
ALIASES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen1.5-4b": "qwen1_5_4b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen3-8b": "qwen3_8b",
    "gemma3-12b": "gemma3_12b",
    "mamba2-130m": "mamba2_130m",
    "arctic-480b": "arctic_480b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "phi3.5-moe": "phi3_5_moe",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def _module(name: str):
    key = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; know {list(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{key}")


def get(name: str) -> ModelConfig:
    return _module(name).config()


def get_smoke(name: str) -> ModelConfig:
    return _module(name).smoke()


def all_configs() -> dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "ALIASES", "get", "get_smoke", "all_configs",
           "ModelConfig", "ShapeCfg", "SHAPES", "ALL_SHAPES", "shapes_for"]
