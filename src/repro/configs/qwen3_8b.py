"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk-norm  [hf:Qwen/Qwen3-8B].

Per-head RMSNorm on q/k before RoPE (``qk_norm=True``).
``long_500k`` SKIPPED (full attention).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        rope_base=1_000_000.0,
        qk_norm=True,
        mlp_kind="swiglu",
        act="silu",
        tie_embeddings=False,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="chunked", q_chunk=16, k_chunk=16, remat="none")
