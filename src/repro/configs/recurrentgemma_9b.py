"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, RG-LRU + local attention 1:2  [arXiv:2402.19427].

Pattern (R,R,A): 12 scanned triples + a (R,R) tail = 38 layers.  Attention
layers are LOCAL (window 2048, MQA kv=1 replicated); recurrent layers are
RG-LRU (lru_width 4096, block-diagonal gates over 16 blocks) computed with
an associative scan.  Gemma conventions ((1+w) norm, sqrt(d) embed scale,
GEGLU, tied head); RoPE on half the head dim (Griffin).
``long_500k`` RUNS (constant-size RG-LRU state + 2048-slot ring caches).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        pattern=("R", "R", "L"),
        window=2048,
        rope_base=10_000.0,
        rope_fraction=0.5,
        lru_width=4096,
        rnn_blocks=16,
        norm_plus_one=True,
        scale_embed=True,
        mlp_kind="geglu",
        act="gelu",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        supports_long_context=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, window=16, lru_width=64, rnn_blocks=4,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="chunked", q_chunk=16, k_chunk=16, remat="none")
