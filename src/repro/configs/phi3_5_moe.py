"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2  [hf:microsoft/Phi-3.5-MoE-instruct].

16 experts over the 16-way model axis: exactly one expert per TP group
(EP degree = experts).  ``long_500k`` SKIPPED (full attention).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3_5_moe",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32064,
        n_experts=16,
        top_k=2,
        capacity_factor=1.25,
        norm_eps=1e-5,
        mlp_kind="swiglu",
        act="silu",
        tie_embeddings=False,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        microbatches=2,
        supports_long_context=False,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, n_experts=4, microbatches=1,
        capacity_factor=8.0,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="chunked", q_chunk=16, k_chunk=16, remat="none")
