"""qwen1.5-4b [dense] — 40L d_model=2560 20H (MHA kv=20) d_ff=6912
vocab=151936, QKV bias  [hf:Qwen/Qwen1.5-4B].

20 heads do not divide the 16-way model axis: q-heads are padded to 32
with zero-initialized wq rows / wo columns (numerics exact; DESIGN.md §5).
``long_500k`` SKIPPED (full attention).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1_5_4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        head_dim=128,
        d_ff=6912,
        vocab_size=151936,
        rope_base=5_000_000.0,
        qkv_bias=True,
        mlp_kind="swiglu",
        act="silu",
        tie_embeddings=False,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="chunked", q_chunk=16, k_chunk=16, remat="none")
