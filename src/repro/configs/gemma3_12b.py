"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global attention, 128k context
[hf:google/gemma-3-12b-pt].

Pattern (L,L,L,L,L,A) x 8 scan groups; local window 1024; local layers use
rope base 10k, global 1M (``rope_base_local``).  Gemma conventions:
(1+w) RMSNorm, sandwich norms, embeddings scaled by sqrt(d), tied head,
GEGLU.  ``long_500k`` RUNS: local layers hold a 1024-slot ring cache and
the 8 global layers flash-decode against a sequence-sharded cache.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3_12b",
        family="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        pattern=("L", "L", "L", "L", "L", "A"),
        window=1024,
        rope_base=1_000_000.0,
        rope_base_local=10_000.0,
        qk_norm=True,                # gemma3 adds qk-norm
        norm_plus_one=True,
        sandwich_norm=True,
        scale_embed=True,
        mlp_kind="geglu",
        act="gelu",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        supports_long_context=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, window=16,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="chunked", q_chunk=16, k_chunk=16, remat="none")
