"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + DENSE RESIDUAL
[hf:Snowflake/snowflake-arctic-base].

Arctic's dense-MoE hybrid: every layer runs a dense SwiGLU FFN in parallel
with the 128-expert top-2 routed FFN.  56 q-heads pad to 64 under 16-way
TP; kv=8 replicated; experts sharded 8-per-chip over "model" (EP).
Training uses Adafactor (factored second moment) — Adam moments for 480B
params do not fit 16 GB/chip even fully sharded (DESIGN.md §5).
``long_500k`` SKIPPED (full attention).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic_480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        n_experts=128,
        top_k=2,
        capacity_factor=1.25,
        dense_residual=True,
        mlp_kind="swiglu",
        act="silu",
        tie_embeddings=False,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        optimizer="adafactor",
        microbatches=4,
        supports_long_context=False,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, n_experts=4, microbatches=1,
        capacity_factor=8.0,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="chunked", q_chunk=16, k_chunk=16, remat="none")
