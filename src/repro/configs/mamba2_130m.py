"""mamba2-130m [ssm] — 24L d_model=768, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality)  [arXiv:2405.21060].

Pure Mamba2 blocks (no MLP: d_ff=0): d_inner = 2*768 = 1536, head_dim 64
-> 24 SSD value heads (padded to 32 under 16-way TP), n_groups=1 B/C.
``long_500k`` RUNS (constant-memory recurrent decode).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=12,        # unused (attention-free); kept for bookkeeping
        n_kv_heads=12,
        head_dim=64,
        d_ff=0,
        vocab_size=50280,
        pattern=("M",),
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        d_conv=4,
        norm_eps=1e-5,
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        supports_long_context=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssd_chunk=16,
        param_dtype="float32", compute_dtype="float32", remat="none")
