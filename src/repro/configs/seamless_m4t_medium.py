"""seamless-m4t-medium [audio] — encoder-decoder, multimodal (arXiv:2308.11596).

12L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206

Backbone only, per the brief: the speech frontend is a STUB supplying
precomputed frame embeddings (dim 1024) to the encoder; the text decoder
carries the assigned shapes (decode shapes lower the *decoder* step against
a frozen encoder cache).  Deviations noted in DESIGN.md: sinusoidal
positions -> RoPE.  ``long_500k`` SKIPPED (full attention).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless_m4t_medium",
        family="encdec",
        n_layers=12,                 # decoder
        enc_layers=12,               # encoder
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        norm_kind="layernorm",
        norm_eps=1e-5,
        mlp_kind="mlp",
        act="gelu",
        qkv_bias=True,
        tie_embeddings=True,
        frontend_dim=1024,           # speech-encoder hidden (stub)
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, frontend_dim=32,
        param_dtype="float32", compute_dtype="float32",
        attn_impl="chunked", q_chunk=16, k_chunk=16, remat="none")
