"""Checkpointing: per-leaf .npy shards + a JSON index, written by a
background thread, restored onto ANY mesh (elastic reshard-on-load).

Design points for 1000+-node scale (DESIGN.md §5):

* **Sharded save**: in a multi-host deployment each host writes only the
  leaf shards it owns (``jax.experimental.multihost_utils`` addressable
  shards); on this single-host container that degenerates to full leaves,
  but the directory format (one file per leaf x shard-group) is the same.
* **Async**: ``save()`` snapshots device arrays to host memory
  (device_get) and hands the file I/O to a writer thread — the step loop
  resumes immediately (the paper's "never stall the accelerator",
  C6-as-checkpointing).
* **Elastic restore**: files carry logical leaf paths, not device
  placements.  ``restore(target_shardings=...)`` device_puts each leaf
  with the *new* mesh's NamedSharding, so a job restarted on a different
  pod count / mesh shape resumes transparently (tests/test_runtime.py).
* **Atomicity**: writes go to ``step_K.tmp/`` then os.rename to
  ``step_K/`` — a crash mid-write never corrupts the latest checkpoint.
* **Retention**: keep the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any
_SEP = "::"


def _fault_trip(site: str, detail: str = "", step=None):
    # lazy: importing repro.runtime.faults at module scope would cycle
    # (runtime/__init__ -> supervisor -> repro.checkpoint -> here)
    from repro.runtime.faults import trip
    return trip(site, detail, step)


def _flatten_with_names(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    extra: Optional[dict] = None) -> str:
    """Synchronous atomic save; returns the final directory."""
    _fault_trip("checkpoint.save", detail=directory, step=step)
    names, leaves, _ = _flatten_with_names(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    index = {"step": step, "leaves": [], "extra": extra or {}}
    host = jax.device_get(leaves)
    for i, (name, leaf) in enumerate(zip(names, host)):
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), np.asarray(leaf), allow_pickle=False)
        index["leaves"].append({"name": name, "file": fn,
                                "dtype": str(np.asarray(leaf).dtype),
                                "shape": list(np.asarray(leaf).shape)})
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, like: Pytree, step: Optional[int] = None,
                    target_shardings: Optional[Pytree] = None
                    ) -> tuple[int, Pytree, dict]:
    """Restore into the structure of ``like``; placement from
    ``target_shardings`` (same structure) if given — elastic reshard."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)
    names, leaves, treedef = _flatten_with_names(like)
    by_name = {e["name"]: e for e in index["leaves"]}
    sh_leaves = (treedef.flatten_up_to(target_shardings)
                 if target_shardings is not None else [None] * len(leaves))
    out = []
    for name, leaf, sh in zip(names, leaves, sh_leaves):
        e = by_name[name]
        arr = np.load(os.path.join(d, e["file"]), allow_pickle=False)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != "
                             f"target {leaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr.astype(leaf.dtype)))
    return step, jax.tree_util.tree_unflatten(treedef, out), index["extra"]


class CheckpointManager:
    """Async writer + retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Pytree, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.wait()  # one in-flight save at a time
        host = jax.device_get(tree)  # snapshot NOW; step loop may mutate

        def work():
            try:
                save_checkpoint(self.directory, step, host, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self._raise()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise()

    def _raise(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore_latest(self, like: Pytree, target_shardings=None):
        return load_checkpoint(self.directory, like,
                               target_shardings=target_shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
