"""Measured autotuner for per-segment layout & kernel tiling (HONEI /
CrystalGPU applied to Ripple's polymorphic layout).

The layout solver (``core/executor.py``) picks AoS/SoA/AoSoA by static
heuristics and kernels run with fixed default tile shapes — the paper's
"near-optimal bandwidth across targets" claim, asserted but never
measured.  This module measures it: for an ``Executor``'s plan it

1. benchmarks the heuristic baseline with real timed executions of the
   plan's region executables (``timing.time_fn_split`` — the same
   harness every benchmark table uses), while recording which Pallas
   kernels the trace consults (``tiles.record_tile_use``);
2. coordinate-descends over the candidate space: per record state key
   the halo-feasible layout set the PR-1 solver computes
   (``core.executor.layout_candidates``), then per consulted kernel its
   ``tile_candidates()`` hook, accepting a candidate only when its
   steady-state median beats the incumbent;
3. commits the argmin configuration (a :class:`TuningDecision`) and
   persists it in the on-disk cache (``repro.tuning.cache``) keyed by
   heuristic plan signature × device kind × jax version, so a second
   process (the serving pattern) loads it with ZERO timed measurements.

``Executor(tune="auto")`` drives this at construction; ``tune="load"``
only consults the cache (heuristics on a miss);
``plan.describe_tuning()`` renders what was measured, chosen, and why.
``STATS["measurements"]`` counts timed candidate executions — tests
assert it stays 0 on a cache hit.
"""

from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field as dfield
from typing import Any, Optional

from . import cache as cache_lib
from . import tiles as tiles_lib
from .timing import time_fn_split

__all__ = ["Measurement", "TuningDecision", "STATS", "reset_stats",
           "tuning_key", "resolve_tuning", "measure_plan"]

# per-process tuner counters; tests assert measurements == 0 on cache hits
STATS = {"measurements": 0, "cache_hits": 0, "cache_misses": 0, "stores": 0}

# how many graph steps one timed call executes (relative comparisons only
# need steady-state per-step cost to dominate fixed dispatch overhead)
TUNE_STEPS = 2
TUNE_ITERS = 5

# makes the baseline probe's plan signature unique per tuning session so
# its trace really runs (and tile-use recording sees every kernel) even
# when an identical heuristic plan was already compiled in-process
_probe_nonce = itertools.count(1)


def reset_stats() -> None:
    """Zero the per-process tuner counters (tests)."""
    for k in STATS:
        STATS[k] = 0


@dataclass(frozen=True)
class Measurement:
    """One timed candidate configuration.

    ``kind`` is ``'baseline'`` (the untouched heuristic plan),
    ``'layout'`` (``key`` = state key, ``candidate`` = layout name) or
    ``'tile'`` (``key`` = kernel name, ``candidate`` = tile repr);
    ``chosen`` marks the rows of the committed configuration."""

    kind: str
    key: str
    candidate: str
    first_ms: float
    steady_ms: float
    chosen: bool = False

    def describe(self) -> str:
        what = ("heuristic plan" if self.kind == "baseline"
                else f"{self.kind} {self.key}={self.candidate}")
        mark = "  [chosen]" if self.chosen else ""
        return (f"{what}: steady {self.steady_ms:.4f} ms "
                f"(first {self.first_ms:.1f} ms){mark}")


@dataclass
class TuningDecision:
    """The tuner's committed configuration for one plan.

    ``layouts`` maps state keys to the measured-best storage layout
    (only keys that beat the heuristic appear), ``tiles`` maps kernel
    names to the measured-best tile config.  ``source`` says where the
    decision came from: ``'measured'`` (this process timed candidates),
    ``'cache'`` (loaded from the persistent cache — zero measurements)
    or ``'heuristic'`` (``tune="load"`` missed the cache; nothing
    applied).  :meth:`describe` renders the full measurement log —
    what was measured, what won, and by how much."""

    source: str
    cache_key: str
    layouts: dict[str, Any] = dfield(default_factory=dict)   # key -> Layout
    tiles: dict[str, Any] = dfield(default_factory=dict)     # kernel -> tile
    baseline_ms: Optional[float] = None
    tuned_ms: Optional[float] = None
    measurements: list[Measurement] = dfield(default_factory=list)

    @property
    def applied(self) -> bool:
        """True when the decision changes anything vs the heuristics."""
        return bool(self.layouts or self.tiles)

    def describe(self) -> str:
        """Human-readable tuning report (``plan.describe_tuning()``)."""
        lines = [f"tuning ({self.source}, cache key {self.cache_key}):"]
        if self.baseline_ms is not None and self.tuned_ms is not None:
            ratio = self.baseline_ms / max(self.tuned_ms, 1e-9)
            lines[0] += (f" heuristic {self.baseline_ms:.4f} ms -> tuned "
                         f"{self.tuned_ms:.4f} ms ({ratio:.2f}x)")
        if not self.applied:
            lines.append("  heuristic configuration kept (no measured "
                         "candidate beat it)" if self.source != "heuristic"
                         else "  heuristic configuration in effect (cache "
                         "miss under tune=\"load\" — nothing measured)")
        for name in sorted(self.layouts):
            lines.append(f"  layout {name} -> "
                         f"{getattr(self.layouts[name], 'name', self.layouts[name])}")
        for name in sorted(self.tiles):
            lines.append(f"  tile {name} -> {self.tiles[name]!r}")
        if self.measurements:
            lines.append("  measured:")
            lines.extend(f"    {m.describe()}" for m in self.measurements)
        return "\n".join(lines)


# -- cache (de)serialization ---------------------------------------------------

def tuning_key(executor) -> str:
    """The persistent-cache key of an executor's plan: heuristic plan
    signature × the full device assortment (kinds × counts × process
    count — ``cache.device_assortment``, NOT just ``devices()[0]``, so
    heterogeneous or multi-host meshes never reuse a measurement taken
    on different hardware) × jax version.  Stable across processes for
    graphs whose node functions the plan signature can key structurally
    (plain functions / closures over provable values)."""
    import jax

    raw = repr(("repro-tune-v2", executor.plan.signature,
                cache_lib.device_assortment(), jax.__version__))
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


def _payload(dec: TuningDecision) -> dict:
    return {
        "layouts": {k: v.name for k, v in dec.layouts.items()},
        "tiles": dict(dec.tiles),
        "baseline_ms": dec.baseline_ms,
        "tuned_ms": dec.tuned_ms,
        "measurements": [
            {"kind": m.kind, "key": m.key, "candidate": m.candidate,
             "first_ms": m.first_ms, "steady_ms": m.steady_ms,
             "chosen": m.chosen} for m in dec.measurements],
    }


def _decision_from_payload(key: str, payload: dict) -> TuningDecision:
    from ..core.layout import Layout

    layouts = {k: Layout[v] for k, v in payload["layouts"].items()}
    tiles = {k: tiles_lib._norm(v) for k, v in payload["tiles"].items()}
    meas = [Measurement(m["kind"], m["key"], m["candidate"],
                        float(m["first_ms"]), float(m["steady_ms"]),
                        bool(m.get("chosen", False)))
            for m in payload.get("measurements", [])]
    return TuningDecision("cache", key, layouts, tiles,
                          payload.get("baseline_ms"),
                          payload.get("tuned_ms"), meas)


# -- driver --------------------------------------------------------------------

def resolve_tuning(executor, mode: str) -> TuningDecision:
    """The tuned decision for ``executor``'s (heuristic) plan.

    ``mode='load'`` never measures: a cache hit applies, a miss keeps
    heuristics.  ``mode='auto'`` measures on a miss and persists the
    result.  Called by ``Executor.__init__`` before the plan is
    finalized."""
    key = tuning_key(executor)
    payload = cache_lib.load(key)
    if payload is not None:
        try:
            dec = _decision_from_payload(key, payload)
        except (KeyError, TypeError, ValueError):
            cache_lib._warn_once(cache_lib.cache_path(key),
                                 "undecodable decision")
            payload = None
        else:
            STATS["cache_hits"] += 1
            return dec
    STATS["cache_misses"] += 1
    if mode == "load":
        return TuningDecision("heuristic", key)
    # cross-process serialization: the first process to take the key's
    # lock measures and persists; any process that waited re-checks the
    # cache under the lock and loads instead of duplicating the
    # measurement (cache.tuning_lock degrades to unlocked on trouble)
    with cache_lib.tuning_lock(key) as locked:
        if locked:
            # misses are never memoized, so this re-reads the FILE — it
            # sees anything a lock holder persisted while we waited
            payload = cache_lib.load(key)
            if payload is not None:
                try:
                    dec = _decision_from_payload(key, payload)
                except (KeyError, TypeError, ValueError):
                    pass
                else:
                    STATS["cache_hits"] += 1
                    return dec
        dec = measure_plan(executor, key)
        cache_lib.store(key, _payload(dec))
        STATS["stores"] += 1
    return dec


def measure_plan(executor, key: str) -> TuningDecision:
    """Coordinate-descent search over layouts × kernel tiles, every
    candidate timed as a real execution of the candidate plan's region
    executables (fresh ``Executor`` per candidate — the executable cache
    keys tile config and layout plan, so measurements never alias)."""
    from ..core import executor as executor_lib

    Executor = executor_lib.Executor
    graph, mesh = executor.graph, executor.mesh
    nonce = next(_probe_nonce)
    candidate_sigs: list[tuple] = []

    def bench(layouts, tiles, probe=False):
        tile_cfg = dict(executor._tile_config)
        if probe:
            tile_cfg["__tune_probe__"] = nonce
        tile_cfg.update(tiles)
        ex = Executor(graph, mesh=mesh, donate=executor.donate,
                      layout_overrides={**executor._layout_overrides,
                                        **layouts},
                      schedule=executor.schedule,
                      regions=executor.regions_enabled,
                      async_regions=executor.async_regions,
                      tile_overrides=tile_cfg)
        candidate_sigs.append(ex._plan_sig)
        state = ex.init_state(**executor._tune_inputs)

        if executor.donate:
            # measure under the plan's REAL donation setting: donation
            # consumes input buffers, so copy the initial state (the
            # caller's tune_inputs must survive every candidate) and chain
            # each timed call on the previous output
            import jax
            import jax.numpy as jnp

            carry = {"st": jax.tree_util.tree_map(jnp.array, state)}

            def run_once():
                carry["st"] = ex.run(dict(carry["st"]), TUNE_STEPS)
                return carry["st"]
        else:
            def run_once():
                return ex.run(dict(state), TUNE_STEPS)

        recorder = tiles_lib.record_tile_use() if probe else None
        if recorder is not None:
            with recorder as used:
                first, steady = time_fn_split(run_once, iters=TUNE_ITERS)
        else:
            used = None
            first, steady = time_fn_split(run_once, iters=TUNE_ITERS)
        STATS["measurements"] += 1
        return first, steady, used, ex._plan_sig

    measurements: list[Measurement] = []
    best_layouts: dict[str, Any] = {}
    best_tiles: dict[str, Any] = {}
    best_sig = None
    try:
        first, base_ms, used, _sig = bench({}, {}, probe=True)
        measurements.append(Measurement("baseline", "plan", "heuristic",
                                        first, base_ms))
        best_ms = base_ms

        # -- layout axis: halo-feasible set per non-pinned record key ------
        heuristic = dict(executor.plan.initial)
        for name, cands in sorted(
                executor_lib.layout_candidates(executor).items()):
            for lay in cands:
                if lay is heuristic.get(name):
                    continue   # covered by the incumbent measurement
                f, s, _, sig = bench({**best_layouts, name: lay}, best_tiles)
                m = Measurement("layout", name, lay.name, f, s)
                measurements.append(m)
                if s < best_ms:
                    best_ms, best_sig = s, sig
                    best_layouts = {**best_layouts, name: lay}

        # -- tile axis: per consulted kernel, its tile_candidates() hook ---
        for kernel in sorted(used or {}):
            uses = used[kernel]
            defaults = {d for _, d in uses}
            cand_sets = [set(tiles_lib.tile_candidates(kernel, shape))
                         for shape, _ in uses]
            cands = set.intersection(*cand_sets) if cand_sets else set()
            for tile in sorted(cands, key=repr):
                if tile in defaults:
                    continue   # the default is the incumbent
                f, s, _, sig = bench(best_layouts,
                                     {**best_tiles, kernel: tile})
                m = Measurement("tile", kernel, repr(tile), f, s)
                measurements.append(m)
                if s < best_ms:
                    best_ms, best_sig = s, sig
                    best_tiles = {**best_tiles, kernel: tile}
    finally:
        # drop the losing candidates' executables; the winner benched under
        # the caller's own donation setting (donation is part of the plan
        # signature), so the caller's executor fetches it straight from the
        # cache with zero new traces
        for sig in candidate_sigs:
            if sig != best_sig:
                executor_lib._EXECUTABLE_CACHE.pop(sig, None)

    chosen_keys = ({("layout", k, v.name) for k, v in best_layouts.items()}
                   | {("tile", k, repr(v)) for k, v in best_tiles.items()})
    if not chosen_keys:
        chosen_keys = {("baseline", "plan", "heuristic")}
    measurements = [
        Measurement(m.kind, m.key, m.candidate, m.first_ms, m.steady_ms,
                    chosen=(m.kind, m.key, m.candidate) in chosen_keys)
        for m in measurements]
    return TuningDecision("measured", key, best_layouts, best_tiles,
                          baseline_ms=base_ms, tuned_ms=best_ms,
                          measurements=measurements)
