"""Measured autotuner — JOINT layout × tile search with HLO cost-model
pruning (HONEI / CrystalGPU applied to Ripple's polymorphic layout).

The layout solver (``core/executor.py``) picks AoS/SoA/AoSoA by static
heuristics and kernels run with fixed default tile shapes — the paper's
"near-optimal bandwidth across targets" claim, asserted but never
measured.  This module measures it: for an ``Executor``'s plan it

1. benchmarks the heuristic baseline with real timed executions of the
   plan's region executables (``timing.time_fn_split`` — the same
   harness every benchmark table uses), while recording which Pallas
   kernels the trace consults (``tiles.record_tile_use``), and compiles
   the baseline's device-region HLO into a traffic model
   (``analysis.hlo.CostRanker``);
2. proposes the JOINT candidate space: the cross product of per-key
   halo-feasible layouts (``core.executor.layout_candidates``) × per
   consulted kernel its ``tile_candidates()`` hook, plus PER-SEGMENT
   layout refinements for keys live in several segments (the in-trace
   relayout machinery makes mixed-segment layouts value-exact);
3. ranks every proposal with the HLO cost model (baseline bytes + an
   analytic relayout-traffic and strided-access penalty) so only the
   cheapest fraction (:class:`TuneBudget`) is ever measured;
4. times the surviving candidates with real executions.  Each
   candidate's timing loop stops early once its running median is
   statistically dominated by the incumbent
   (``timing.time_fn_budget``), and the search itself stops once the
   incumbent survives ``TuneBudget.neighborhoods`` consecutive
   candidates;
5. commits the argmin configuration (a :class:`TuningDecision` —
   including any per-segment layout assignments) and persists it in the
   on-disk cache (``repro.tuning.cache``, schema v3) keyed by heuristic
   plan signature × device assortment × jax version, so a second
   process (the serving pattern) loads it with ZERO timed measurements.
   Entries written by the v2 coordinate-descent tuner are
   migration-read (:func:`legacy_tuning_key`) and re-persisted under
   the v3 key without re-measurement when still feasible.

``Executor(tune="auto", tune_budget=...)`` drives this at construction;
``tune="load"`` only consults the cache (heuristics on a miss);
``plan.describe_tuning()`` renders what was proposed, pruned, measured,
chosen, and why.  ``STATS["measurements"]`` counts timed candidate
executions — tests assert it stays 0 on a cache hit.
"""

from __future__ import annotations

import hashlib
import itertools
import math
from dataclasses import dataclass, field as dfield
from typing import Any, Optional

from . import cache as cache_lib
from . import tiles as tiles_lib
from .timing import time_fn_budget

__all__ = ["Measurement", "TuneBudget", "TuningDecision", "STATS",
           "reset_stats", "tuning_key", "legacy_tuning_key",
           "resolve_tuning", "measure_plan"]

# per-process tuner counters; tests assert measurements == 0 on cache hits
STATS = {"measurements": 0, "cache_hits": 0, "cache_misses": 0, "stores": 0,
         "proposed": 0, "pruned": 0, "migrations": 0}

# how many graph steps one timed call executes (relative comparisons only
# need steady-state per-step cost to dominate fixed dispatch overhead)
TUNE_STEPS = 2
TUNE_ITERS = 5

# makes the baseline probe's plan signature unique per tuning session so
# its trace really runs (and tile-use recording sees every kernel) even
# when an identical heuristic plan was already compiled in-process
_probe_nonce = itertools.count(1)


def reset_stats() -> None:
    """Zero the per-process tuner counters (tests)."""
    for k in STATS:
        STATS[k] = 0


@dataclass(frozen=True)
class TuneBudget:
    """Measurement budget for the joint search (``tune_budget=``).

    ``max_measure_frac`` bounds the fraction of proposed joint
    candidates that survive HLO cost-model pruning into real timed
    measurement (clamped to at least ``min_measure`` and at most
    ``max_measure`` when set).  ``neighborhoods`` stops the search once
    the incumbent survives that many consecutive measured candidates
    without being beaten.  ``dominate_factor`` stops one CANDIDATE's
    timing loop early (after ``min_timing_iters`` timed calls) once its
    running median exceeds ``incumbent × factor`` — it cannot win, so
    the remaining iterations are skipped.  ``measure_all`` disables
    pruning and early stopping entirely (conformance testing).
    ``max_proposals`` caps combinatorial blow-up of the joint space."""

    max_measure_frac: float = 0.3
    min_measure: int = 2
    max_measure: Optional[int] = None
    neighborhoods: int = 3
    dominate_factor: float = 1.15
    min_timing_iters: int = 2
    measure_all: bool = False
    max_proposals: int = 512

    @classmethod
    def coerce(cls, value) -> "TuneBudget":
        """A :class:`TuneBudget` from None (defaults), a dict of fields,
        or an existing instance (returned as-is)."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        raise TypeError(f"tune_budget must be None, a dict or a "
                        f"TuneBudget, got {type(value).__name__}")

    def measure_count(self, proposed: int) -> int:
        """How many of ``proposed`` candidates the budget measures."""
        if proposed <= 0:
            return 0
        if self.measure_all:
            return proposed
        k = math.ceil(self.max_measure_frac * proposed)
        k = max(k, min(self.min_measure, proposed))
        if self.max_measure is not None:
            k = min(k, self.max_measure)
        return min(k, proposed)


@dataclass(frozen=True)
class Measurement:
    """One timed candidate configuration.

    ``kind`` is ``'baseline'`` (the untouched heuristic plan) or
    ``'joint'`` (one joint layout×tile candidate; ``candidate`` is its
    compact config label, e.g. ``'p=SOA,saxpy=2048'``).
    ``predicted_bytes`` is the HLO cost model's traffic estimate that
    ranked the candidate (0 when ranking was unavailable), ``iters``
    how many timed calls the steady median used, ``early_stopped``
    whether the timing loop was cut short because the candidate was
    statistically dominated.  ``chosen`` marks the committed row."""

    kind: str
    key: str
    candidate: str
    first_ms: float
    steady_ms: float
    chosen: bool = False
    predicted_bytes: float = 0.0
    iters: int = 0
    early_stopped: bool = False

    def describe(self) -> str:
        what = ("heuristic plan" if self.kind == "baseline"
                else f"{self.kind} {self.key}={self.candidate}")
        mark = "  [chosen]" if self.chosen else ""
        extra = ""
        if self.predicted_bytes:
            extra += f", predicted {self.predicted_bytes / 1e6:.3f} MB"
        if self.early_stopped:
            extra += f", dominated after {self.iters} iters"
        return (f"{what}: steady {self.steady_ms:.4f} ms "
                f"(first {self.first_ms:.1f} ms{extra}){mark}")


@dataclass
class TuningDecision:
    """The tuner's committed configuration for one plan.

    ``layouts`` maps state keys to the measured-best storage layout
    (only keys that beat the heuristic appear), ``tiles`` maps kernel
    names to the measured-best tile config, and ``segment_layouts``
    holds any PER-SEGMENT layout assignments the joint search committed
    (segment index -> key -> Layout; the executor merges these into its
    ``segment_layout_overrides``).  ``proposed`` / ``pruned`` /
    ``measured`` count the joint search space: how many candidates were
    proposed, how many the HLO cost ranking (plus early stopping)
    skipped, and how many were actually timed.  ``source`` says where
    the decision came from: ``'measured'`` (this process timed
    candidates), ``'cache'`` (loaded from the persistent cache — zero
    measurements), ``'migrated'`` (a v2 coordinate-tuner entry re-keyed
    under the v3 schema — also zero measurements) or ``'heuristic'``
    (``tune="load"`` missed the cache; nothing applied).
    :meth:`describe` renders the full measurement log."""

    source: str
    cache_key: str
    layouts: dict[str, Any] = dfield(default_factory=dict)   # key -> Layout
    tiles: dict[str, Any] = dfield(default_factory=dict)     # kernel -> tile
    baseline_ms: Optional[float] = None
    tuned_ms: Optional[float] = None
    measurements: list[Measurement] = dfield(default_factory=list)
    segment_layouts: dict[int, dict[str, Any]] = dfield(default_factory=dict)
    proposed: int = 0
    pruned: int = 0
    measured: int = 0

    @property
    def applied(self) -> bool:
        """True when the decision changes anything vs the heuristics."""
        return bool(self.layouts or self.tiles or self.segment_layouts)

    def describe(self) -> str:
        """Human-readable tuning report (``plan.describe_tuning()``)."""
        lines = [f"tuning ({self.source}, cache key {self.cache_key}):"]
        if self.baseline_ms is not None and self.tuned_ms is not None:
            ratio = self.baseline_ms / max(self.tuned_ms, 1e-9)
            lines[0] += (f" heuristic {self.baseline_ms:.4f} ms -> tuned "
                         f"{self.tuned_ms:.4f} ms ({ratio:.2f}x)")
        if self.proposed:
            lines.append(f"  search space: {self.proposed} proposed / "
                         f"{self.pruned} pruned by HLO cost ranking / "
                         f"{self.measured} measured")
        if not self.applied:
            lines.append("  heuristic configuration kept (no measured "
                         "candidate beat it)" if self.source != "heuristic"
                         else "  heuristic configuration in effect (cache "
                         "miss under tune=\"load\" — nothing measured)")
        for name in sorted(self.layouts):
            lines.append(f"  layout {name} -> "
                         f"{getattr(self.layouts[name], 'name', self.layouts[name])}")
        for si in sorted(self.segment_layouts):
            for name in sorted(self.segment_layouts[si]):
                lay = self.segment_layouts[si][name]
                lines.append(f"  segment {si} layout {name} -> "
                             f"{getattr(lay, 'name', lay)}")
        for name in sorted(self.tiles):
            lines.append(f"  tile {name} -> {self.tiles[name]!r}")
        if self.measurements:
            lines.append("  measured:")
            lines.extend(f"    {m.describe()}" for m in self.measurements)
        return "\n".join(lines)


# -- cache (de)serialization ---------------------------------------------------

def tuning_key(executor) -> str:
    """The persistent-cache key of an executor's plan: heuristic plan
    signature × the full device assortment (kinds × counts × process
    count — ``cache.device_assortment``, NOT just ``devices()[0]``, so
    heterogeneous or multi-host meshes never reuse a measurement taken
    on different hardware) × jax version.  Stable across processes for
    graphs whose node functions the plan signature can key structurally
    (plain functions / closures over provable values)."""
    import jax

    raw = repr(("repro-tune-v3", executor.plan.signature,
                cache_lib.device_assortment(), jax.__version__))
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


def legacy_tuning_key(executor) -> str:
    """The key the v2 coordinate-descent tuner would have used for this
    plan — consulted on a v3 miss to migrate old entries forward.  Note
    the v2 key hashed the v2 plan signature; the plan signature itself
    was bumped alongside the schema, so this reconstructs the legacy
    key from the CURRENT signature with the old prefix (sufficient for
    entries whose plan signature components survived the bump, and a
    harmless miss otherwise)."""
    import jax

    raw = repr(("repro-tune-v2", executor.plan.signature,
                cache_lib.device_assortment(), jax.__version__))
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


def _payload(dec: TuningDecision) -> dict:
    return {
        "layouts": {k: v.name for k, v in dec.layouts.items()},
        "tiles": dict(dec.tiles),
        "segment_layouts": {
            str(si): {k: v.name for k, v in d.items()}
            for si, d in dec.segment_layouts.items()},
        "baseline_ms": dec.baseline_ms,
        "tuned_ms": dec.tuned_ms,
        "proposed": dec.proposed,
        "pruned": dec.pruned,
        "measured": dec.measured,
        "measurements": [
            {"kind": m.kind, "key": m.key, "candidate": m.candidate,
             "first_ms": m.first_ms, "steady_ms": m.steady_ms,
             "chosen": m.chosen, "predicted_bytes": m.predicted_bytes,
             "iters": m.iters, "early_stopped": m.early_stopped}
            for m in dec.measurements],
    }


def _decision_from_payload(key: str, payload: dict,
                           source: str = "cache") -> TuningDecision:
    from ..core.layout import Layout

    layouts = {k: Layout[v] for k, v in payload["layouts"].items()}
    tiles = {k: tiles_lib._norm(v) for k, v in payload["tiles"].items()}
    seg_layouts = {
        int(si): {k: Layout[v] for k, v in d.items()}
        for si, d in payload.get("segment_layouts", {}).items()}
    meas = [Measurement(m["kind"], m["key"], m["candidate"],
                        float(m["first_ms"]), float(m["steady_ms"]),
                        bool(m.get("chosen", False)),
                        float(m.get("predicted_bytes", 0.0)),
                        int(m.get("iters", 0)),
                        bool(m.get("early_stopped", False)))
            for m in payload.get("measurements", [])]
    return TuningDecision(source, key, layouts, tiles,
                          payload.get("baseline_ms"),
                          payload.get("tuned_ms"), meas,
                          segment_layouts=seg_layouts,
                          proposed=int(payload.get("proposed", 0)),
                          pruned=int(payload.get("pruned", 0)),
                          measured=int(payload.get("measured", 0)))


def _migrate_legacy(executor, key: str) -> Optional[TuningDecision]:
    """Migration-read a v2 coordinate-tuner cache entry for this plan.

    On a v3 miss: load the legacy key at the legacy schema, check that
    the old decision is still FEASIBLE (every tuned layout key is still
    searchable with that layout as a candidate, every tuned kernel
    still has a registered tile hook), and re-persist it under the v3
    key with zero re-measurement.  An infeasible entry warns once and
    returns None (fresh tuning)."""
    from ..core import executor as executor_lib
    from ..core.layout import Layout

    lkey = legacy_tuning_key(executor)
    payload = cache_lib.load(lkey, schema=cache_lib.LEGACY_SCHEMA_VERSION)
    if payload is None:
        return None
    try:
        dec = _decision_from_payload(key, payload, source="migrated")
    except (KeyError, TypeError, ValueError):
        cache_lib._warn_once(cache_lib.cache_path(lkey),
                             "undecodable legacy decision")
        return None
    cands = executor_lib.layout_candidates(executor)
    heuristic = dict(executor.plan.initial)
    for name, lay in dec.layouts.items():
        if not isinstance(lay, Layout):
            lay = Layout[str(lay)]
        feasible = (lay is heuristic.get(name)
                    or (name in cands and lay in cands[name]))
        if not feasible:
            cache_lib._warn_once(
                cache_lib.cache_path(lkey),
                f"legacy tuned layout {name}->{lay.name} is no longer "
                f"feasible for this plan — re-tuning")
            return None
    registered = set(tiles_lib.registered_tile_kernels())
    for kernel in dec.tiles:
        if kernel not in registered:
            cache_lib._warn_once(
                cache_lib.cache_path(lkey),
                f"legacy tuned kernel {kernel!r} has no registered tile "
                f"hook — re-tuning")
            return None
    cache_lib.store(key, _payload(dec))
    STATS["stores"] += 1
    STATS["migrations"] += 1
    return dec


# -- driver --------------------------------------------------------------------

def resolve_tuning(executor, mode: str, budget=None) -> TuningDecision:
    """The tuned decision for ``executor``'s (heuristic) plan.

    ``mode='load'`` never measures: a cache hit (or a feasible migrated
    v2 entry) applies, a miss keeps heuristics.  ``mode='auto'``
    measures on a miss — under ``budget`` (a :class:`TuneBudget`, a
    dict of its fields, or None for defaults) — and persists the
    result.  Called by ``Executor.__init__`` before the plan is
    finalized."""
    key = tuning_key(executor)
    payload = cache_lib.load(key)
    if payload is not None:
        try:
            dec = _decision_from_payload(key, payload)
        except (KeyError, TypeError, ValueError):
            cache_lib._warn_once(cache_lib.cache_path(key),
                                 "undecodable decision")
            payload = None
        else:
            STATS["cache_hits"] += 1
            return dec
    STATS["cache_misses"] += 1
    dec = _migrate_legacy(executor, key)
    if dec is not None:
        return dec
    if mode == "load":
        return TuningDecision("heuristic", key)
    # cross-process serialization: the first process to take the key's
    # lock measures and persists; any process that waited re-checks the
    # cache under the lock and loads instead of duplicating the
    # measurement (cache.tuning_lock degrades to unlocked on trouble)
    with cache_lib.tuning_lock(key) as locked:
        if locked:
            # misses are never memoized, so this re-reads the FILE — it
            # sees anything a lock holder persisted while we waited
            payload = cache_lib.load(key)
            if payload is not None:
                try:
                    dec = _decision_from_payload(key, payload)
                except (KeyError, TypeError, ValueError):
                    pass
                else:
                    STATS["cache_hits"] += 1
                    return dec
        dec = measure_plan(executor, key, budget)
        cache_lib.store(key, _payload(dec))
        STATS["stores"] += 1
    return dec


# -- joint search --------------------------------------------------------------

def _storage_bytes(t) -> float:
    """Logical storage footprint of one state tensor in bytes (layout-
    independent: every storage layout is a permutation of the same
    elements)."""
    import numpy as np

    n = 1
    for d in t.space:
        n *= int(d)
    comps = t.spec.num_components if t.is_record else 1
    return float(n * comps * np.dtype(t.dtype).itemsize)


def _joint_label(layouts, tiles, seg_layouts) -> str:
    """Compact, deterministic label of one joint candidate."""
    parts = [f"{n}={lay.name}" for n, lay in sorted(layouts.items())]
    parts += [f"seg{si}:{n}={lay.name}"
              for si, d in sorted(seg_layouts.items())
              for n, lay in sorted(d.items())]
    parts += [f"{k}={t!r}" for k, t in sorted(tiles.items())]
    return ",".join(parts) or "heuristic"


def measure_plan(executor, key: str, budget=None) -> TuningDecision:
    """JOINT search over per-key layouts × per-kernel tiles (plus
    per-segment layout refinements), HLO-cost-ranked so only the
    budgeted top fraction is measured; every measured candidate is a
    real execution of the candidate plan's region executables (fresh
    ``Executor`` per candidate — the executable cache keys tile config
    and layout plan, so measurements never alias)."""
    from ..analysis.hlo import CostRanker, layout_access_penalty
    from ..core import executor as executor_lib

    budget = TuneBudget.coerce(budget)
    Executor = executor_lib.Executor
    graph, mesh = executor.graph, executor.mesh
    nonce = next(_probe_nonce)
    candidate_sigs: list[tuple] = []

    def bench(layouts, tiles, seg_layouts=None, probe=False,
              stop_above_ms=None):
        tile_cfg = dict(executor._tile_config)
        if probe:
            tile_cfg["__tune_probe__"] = nonce
        tile_cfg.update(tiles)
        seg_over = {si: dict(d)
                    for si, d in executor._segment_overrides.items()}
        for si, d in (seg_layouts or {}).items():
            seg_over.setdefault(si, {}).update(d)
        ex = Executor(graph, mesh=mesh, donate=executor.donate,
                      layout_overrides={**executor._layout_overrides,
                                        **layouts},
                      schedule=executor.schedule,
                      regions=executor.regions_enabled,
                      async_regions=executor.async_regions,
                      tile_overrides=tile_cfg,
                      segment_layout_overrides=seg_over)
        candidate_sigs.append(ex._plan_sig)
        state = ex.init_state(**executor._tune_inputs)

        if executor.donate:
            # measure under the plan's REAL donation setting: donation
            # consumes input buffers, so copy the initial state (the
            # caller's tune_inputs must survive every candidate) and chain
            # each timed call on the previous output
            import jax
            import jax.numpy as jnp

            carry = {"st": jax.tree_util.tree_map(jnp.array, state)}

            def run_once():
                carry["st"] = ex.run(dict(carry["st"]), TUNE_STEPS)
                return carry["st"]
        else:
            def run_once():
                return ex.run(dict(state), TUNE_STEPS)

        recorder = tiles_lib.record_tile_use() if probe else None
        if recorder is not None:
            with recorder as used:
                first, steady, iters_run, dominated = time_fn_budget(
                    run_once, iters=TUNE_ITERS,
                    min_iters=budget.min_timing_iters,
                    stop_above_ms=stop_above_ms)
        else:
            used = None
            first, steady, iters_run, dominated = time_fn_budget(
                run_once, iters=TUNE_ITERS,
                min_iters=budget.min_timing_iters,
                stop_above_ms=stop_above_ms)
        STATS["measurements"] += 1
        return first, steady, iters_run, dominated, used, ex._plan_sig, \
            ex, state

    measurements: list[Measurement] = []
    best_layouts: dict[str, Any] = {}
    best_tiles: dict[str, Any] = {}
    best_segments: dict[int, dict[str, Any]] = {}
    best_sig = None
    proposed = pruned = measured = 0
    try:
        # -- phase 0: baseline probe (times the heuristic plan, records
        # tile use, and supplies the HLO traffic base for ranking) ------
        first, base_ms, _it, _dom, used, _sig, probe_ex, probe_state = \
            bench({}, {}, probe=True)
        measured += 1
        measurements.append(Measurement("baseline", "plan", "heuristic",
                                        first, base_ms, iters=_it))
        best_ms = base_ms

        ranker = None
        try:
            hlo_texts = [probe_ex.region_hlo(probe_state, i)
                         for i, r in enumerate(probe_ex._regions)
                         if r.kind == "device"]
            if hlo_texts:
                ranker = CostRanker(hlo_texts)
        except Exception:
            ranker = None   # non-region plans etc.: rank by penalty only

        # -- phase 1: search axes --------------------------------------
        heuristic = dict(executor.plan.initial)
        layout_axes: dict[str, list] = {}
        for name, cands in sorted(
                executor_lib.layout_candidates(executor).items()):
            base = heuristic.get(name)
            ordered = ([base] if base in cands else []) \
                + [l for l in cands if l is not base]
            layout_axes[name] = ordered

        tile_axes: dict[str, list] = {}
        tile_defaults: dict[str, Any] = {}
        for kernel in sorted(used or {}):
            uses = used[kernel]
            defaults = {d for _, d in uses}
            cand_sets = [set(tiles_lib.tile_candidates(kernel, shape))
                         for shape, _ in uses]
            cands = set.intersection(*cand_sets) if cand_sets else set()
            cands |= defaults
            default = sorted(defaults, key=repr)[0]
            tile_defaults[kernel] = default
            ordered = sorted(
                cands, key=lambda t: (tiles_lib.tile_distance(t, default),
                                      repr(t)))
            if len(ordered) > 1:
                tile_axes[kernel] = ordered

        # -- phase 2: joint proposals ----------------------------------
        lay_names = sorted(layout_axes)
        tile_names = sorted(tile_axes)
        axes = [[(n, v) for v in layout_axes[n]] for n in lay_names] \
            + [[(k, v) for v in tile_axes[k]] for k in tile_names]
        proposals: list[dict] = []
        for combo in itertools.islice(itertools.product(*axes),
                                      budget.max_proposals):
            lay = {n: v for n, v in combo[:len(lay_names)]
                   if v is not heuristic.get(n)}
            til = {k: v for k, v in combo[len(lay_names):]
                   if v != tile_defaults.get(k)}
            proposals.append({"layouts": lay, "tiles": til,
                              "segments": {}})
        # per-segment refinements: a single-(segment, key) layout flip
        # for keys live in >= 2 segments (the relayout machinery keeps
        # mixed-segment assignments value-exact)
        seg_homes: dict[str, list[int]] = {}
        for si, seg in enumerate(executor.plan.per_segment):
            for name in seg:
                if name in layout_axes:
                    seg_homes.setdefault(name, []).append(si)
        for name, sis in sorted(seg_homes.items()):
            if len(sis) < 2 or len(proposals) >= budget.max_proposals:
                continue
            for si in sis:
                for lay in layout_axes[name]:
                    if lay is heuristic.get(name):
                        continue
                    if len(proposals) >= budget.max_proposals:
                        break
                    proposals.append({"layouts": {}, "tiles": {},
                                      "segments": {si: {name: lay}}})
        proposed = len(proposals)

        # -- phase 3: HLO cost ranking ---------------------------------
        def penalty_of(p) -> float:
            try:
                seg_over = {si: dict(d) for si, d
                            in executor._segment_overrides.items()}
                for si, d in p["segments"].items():
                    seg_over.setdefault(si, {}).update(d)
                plan = executor_lib.solve_layouts(
                    executor._segments, executor.tensors,
                    overrides={**executor._layout_overrides,
                               **p["layouts"]},
                    segment_overrides=seg_over)
            except Exception:
                return float("inf")
            pen = 0.0
            for st in plan.relayouts:
                # a relayout reads + writes the whole storage once
                pen += 2.0 * _storage_bytes(executor.tensors[st.tensor])
            for seg in plan.per_segment:
                for name, lay in seg.items():
                    t = executor.tensors.get(name)
                    if t is None or not t.is_record:
                        continue
                    pen += layout_access_penalty(
                        lay.name, _storage_bytes(t),
                        t.spec.num_components)
            return pen

        def tile_dist(p) -> float:
            return sum(tiles_lib.tile_distance(t, tile_defaults[k])
                       for k, t in p["tiles"].items())

        pens = [penalty_of(p) for p in proposals]
        # stable pre-order near-default-first, so cost ties break toward
        # configurations most likely to behave like the baseline
        order = sorted(range(proposed), key=lambda i: tile_dist(
            proposals[i]))
        order = [i for i in order if pens[i] != float("inf")]
        predicted: dict[int, float] = {}
        if ranker is not None:
            ranked = ranker.rank([(str(i), pens[i]) for i in order])
            order = [int(c.label) for c in ranked]
            predicted = {int(c.label): c.predicted_bytes for c in ranked}
        else:
            order.sort(key=lambda i: pens[i])
            predicted = {i: pens[i] for i in order}

        # -- phase 4/5: prune, then measure the survivors --------------
        k = budget.measure_count(proposed)
        survived = taken = 0
        for idx in order:
            if taken >= k:
                break
            p = proposals[idx]
            if not (p["layouts"] or p["tiles"] or p["segments"]):
                continue   # the all-heuristic combo IS the baseline probe
            if not budget.measure_all and survived >= budget.neighborhoods:
                break      # incumbent survived enough joint neighborhoods
            stop = (None if budget.measure_all
                    else best_ms * budget.dominate_factor)
            f, s, iters_run, dominated, _, sig, _, _ = bench(
                p["layouts"], p["tiles"], p["segments"],
                stop_above_ms=stop)
            measured += 1
            taken += 1
            measurements.append(Measurement(
                "joint", "plan",
                _joint_label(p["layouts"], p["tiles"], p["segments"]),
                f, s, predicted_bytes=predicted.get(idx, 0.0),
                iters=iters_run, early_stopped=dominated))
            if s < best_ms:
                best_ms, best_sig = s, sig
                best_layouts = dict(p["layouts"])
                best_tiles = dict(p["tiles"])
                best_segments = {si: dict(d)
                                 for si, d in p["segments"].items()}
                survived = 0
            else:
                survived += 1
        # ``measured`` counts every configuration with timing data (the
        # baseline probe included); everything proposed but never timed
        # was pruned — by the cost ranking or by neighborhood early stop
        pruned = max(proposed - measured, 0)
        STATS["proposed"] += proposed
        STATS["pruned"] += pruned
    finally:
        # drop the losing candidates' executables; the winner benched under
        # the caller's own donation setting (donation is part of the plan
        # signature), so the caller's executor fetches it straight from the
        # cache with zero new traces
        for sig in candidate_sigs:
            if sig != best_sig:
                executor_lib._EXECUTABLE_CACHE.pop(sig, None)

    chosen_label = _joint_label(best_layouts, best_tiles, best_segments)
    measurements = [
        Measurement(m.kind, m.key, m.candidate, m.first_ms, m.steady_ms,
                    chosen=(m.candidate == chosen_label
                            if chosen_label != "heuristic"
                            else m.kind == "baseline"),
                    predicted_bytes=m.predicted_bytes, iters=m.iters,
                    early_stopped=m.early_stopped)
        for m in measurements]
    return TuningDecision("measured", key, best_layouts, best_tiles,
                          baseline_ms=base_ms, tuned_ms=best_ms,
                          measurements=measurements,
                          segment_layouts=best_segments,
                          proposed=proposed, pruned=max(pruned, 0),
                          measured=measured)
