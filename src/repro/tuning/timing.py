"""Shared timing harness — the ONE implementation of first-call vs
steady-state split timing.

Both the measured autotuner (``repro.tuning.search``) and every
benchmark table (``benchmarks/common.py`` re-exports these names) time
through this module, so tuner decisions and benchmark reports are
measured by the same harness: the first call (which pays trace +
compile) is reported separately from the steady-state median, and
per-step numbers never mix in one-off compilation cost.
"""

from __future__ import annotations

import time

import jax

__all__ = ["time_fn", "time_fn_split", "time_fn_budget"]


def time_fn_split(fn, *args, iters: int = 5, warmup: int = 2,
                  **kw) -> tuple[float, float]:
    """``(first_ms, steady_ms)`` — the first call (which pays trace +
    compile) timed separately from the steady-state median, so tables
    never mix one-off compilation cost into per-step numbers.

    ``warmup`` counts total pre-measurement calls (the first, timed one
    included); ``steady_ms`` is the median of ``iters`` calls after it."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args, **kw))
    first = (time.perf_counter() - t0) * 1e3
    for _ in range(max(warmup - 1, 0)):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append((time.perf_counter() - t0) * 1e3)
    times.sort()
    return first, times[len(times) // 2]


def time_fn_budget(fn, *args, iters: int = 5, warmup: int = 2,
                   min_iters: int = 2, stop_above_ms=None,
                   **kw) -> tuple[float, float, int, bool]:
    """``(first_ms, steady_ms, iters_run, dominated)`` — like
    :func:`time_fn_split`, but the steady-state loop stops early once the
    candidate is statistically dominated: after ``min_iters`` timed
    calls, if the RUNNING median already exceeds ``stop_above_ms`` the
    remaining iterations are skipped (``dominated=True``) — the joint
    autotuner's per-candidate measurement budget.  ``stop_above_ms=None``
    reproduces :func:`time_fn_split` exactly."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args, **kw))
    first = (time.perf_counter() - t0) * 1e3
    for _ in range(max(warmup - 1, 0)):
        jax.block_until_ready(fn(*args, **kw))
    times: list[float] = []
    dominated = False
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append((time.perf_counter() - t0) * 1e3)
        if (stop_above_ms is not None and len(times) >= max(min_iters, 1)
                and sorted(times)[len(times) // 2] > stop_above_ms):
            dominated = True
            break
    return first, sorted(times)[len(times) // 2], len(times), dominated


def time_fn(fn, *args, iters: int = 5, warmup: int = 2, **kw) -> float:
    """Median steady-state wall-time per call in ms (jit-compatible:
    blocks on result; compilation excluded — see :func:`time_fn_split`)."""
    return time_fn_split(fn, *args, iters=iters, warmup=warmup, **kw)[1]
