"""Per-kernel tile registry + ambient tile configuration.

This is the kernel-tiling axis of the measured autotuner
(``repro.tuning.search``).  Each Pallas kernel package registers a
``tile_candidates()`` hook (``register_tile_kernel``) that enumerates
the block/tile shapes feasible for a given problem shape, and resolves
its effective block through :func:`resolve_tile`:

* an explicit ``block=`` argument from the caller always wins;
* otherwise the innermost active :func:`tile_scope` override — this is
  how an ``Executor`` applies a tuned (or candidate) tile configuration
  while its region executables trace, without threading a knob through
  every graph-node closure;
* otherwise the kernel's built-in default.

:func:`record_tile_use` captures which kernels a trace actually
consulted (and at which problem shapes), which is how the tuner
discovers a graph's tile search space without introspecting opaque node
functions.

This module is deliberately import-light (no ``repro.core`` imports):
``core/executor.py`` and every ``kernels/*/ops.py`` import it at module
load.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Callable, Iterator, Mapping, Optional

__all__ = [
    "register_tile_kernel",
    "registered_tile_kernels",
    "tile_candidates",
    "tile_distance",
    "resolve_tile",
    "tile_scope",
    "active_tiles",
    "record_tile_use",
]

# kernel name -> candidates fn: (shape tuple) -> sequence of tile configs
_REGISTRY: dict[str, Callable[[tuple[int, ...]], tuple]] = {}
# stack of active override mappings (innermost last)
_SCOPE: list[Mapping[str, Any]] = []
# stack of active recorders: kernel -> set of (shape, default) pairs
_RECORDERS: list[dict[str, set]] = []


def _norm(tile):
    """Hashable, JSON-round-trippable form of a tile config (lists from a
    JSON cache load become tuples)."""
    if isinstance(tile, list):
        return tuple(_norm(t) for t in tile)
    if isinstance(tile, tuple):
        return tuple(_norm(t) for t in tile)
    return tile


def register_tile_kernel(name: str, candidates: Callable) -> Callable:
    """Register kernel ``name``'s ``tile_candidates(shape)`` hook.

    ``candidates`` maps a problem-shape tuple (each kernel documents its
    own convention — e.g. ``(n,)`` for 1-d record kernels, ``(nx, ny)``
    for 2-d stencils) to the tuple of feasible tile configs, including
    the kernel's default when it is feasible.  Returns ``candidates`` so
    it can be used as a decorator.

    Example::

        @partial(register_tile_kernel, "saxpy")
        def tile_candidates(shape):
            (n,) = shape
            return tuple(b for b in (256, 1024, 4096) if n % b == 0)
    """
    _REGISTRY[name] = candidates
    return candidates


def registered_tile_kernels() -> tuple[str, ...]:
    """Names of every kernel with a registered tile hook (sorted)."""
    return tuple(sorted(_REGISTRY))


def tile_candidates(kernel: str, shape) -> tuple:
    """Feasible tile configs of ``kernel`` for a problem ``shape``
    (empty when the kernel registered no hook)."""
    fn = _REGISTRY.get(kernel)
    if fn is None:
        return ()
    return tuple(_norm(t) for t in fn(tuple(shape)))


def tile_distance(tile, default) -> float:
    """Deterministic distance between a tile config and a kernel's
    default: the sum of ``|log2(t / d)|`` over numeric components (nested
    configs recurse; non-numeric components contribute 0 when equal, 1
    when not).  The joint autotuner uses it to order candidates
    near-default-first, so its HLO cost ranking breaks ties toward the
    configurations most likely to behave like the measured baseline."""
    import math

    tile, default = _norm(tile), _norm(default)
    if isinstance(tile, tuple) or isinstance(default, tuple):
        ts = tile if isinstance(tile, tuple) else (tile,)
        ds = default if isinstance(default, tuple) else (default,)
        if len(ts) != len(ds):
            return float(max(len(ts), len(ds)))
        return sum(tile_distance(t, d) for t, d in zip(ts, ds))
    if isinstance(tile, (int, float)) and isinstance(default, (int, float)) \
            and tile > 0 and default > 0:
        return abs(math.log2(tile / default))
    return 0.0 if tile == default else 1.0


def resolve_tile(kernel: str, explicit, default, shape=None):
    """The effective tile for one kernel invocation.

    Precedence: ``explicit`` (the caller's ``block=`` argument) over the
    innermost :func:`tile_scope` override over ``default``.  When a
    :func:`record_tile_use` recorder is active the consultation is
    logged (kernel name, ``shape``, ``default``) — the autotuner's
    search-space discovery.
    """
    if shape is not None and explicit is None:
        # explicit blocks are not tunable call sites: overrides would
        # never reach them, so recording them would waste measurements
        shape = tuple(shape)
        for rec in _RECORDERS:
            rec.setdefault(kernel, set()).add((shape, _norm(default)))
    if explicit is not None:
        return _norm(explicit)
    for scope in reversed(_SCOPE):
        if kernel in scope:
            return _norm(scope[kernel])
    return _norm(default)


@contextmanager
def tile_scope(config: Optional[Mapping[str, Any]]) -> Iterator[None]:
    """Make ``config`` (kernel name -> tile) the ambient tile overrides.

    Scopes nest; the innermost binding of a kernel wins.  The executor
    wraps every region trace in the scope of its (tuned) tile config, so
    the override is baked into the compiled executable and costs nothing
    at steady state.
    """
    if not config:
        yield
        return
    _SCOPE.append(config)
    try:
        yield
    finally:
        _SCOPE.pop()


def active_tiles() -> dict[str, Any]:
    """The merged ambient tile overrides currently in scope."""
    out: dict[str, Any] = {}
    for scope in _SCOPE:
        out.update(scope)
    return out


@contextmanager
def record_tile_use() -> Iterator[dict[str, set]]:
    """Record every :func:`resolve_tile` consultation inside the block.

    Yields a dict ``kernel -> {(shape, default), ...}`` that fills in as
    kernels are consulted (i.e. as node functions trace).  The tuner
    runs its baseline measurement inside this to learn which kernels a
    graph uses and at which shapes.
    """
    rec: dict[str, set] = {}
    _RECORDERS.append(rec)
    try:
        yield rec
    finally:
        _RECORDERS.remove(rec)
