"""Persistent tuning cache — measured decisions survive the process.

One JSON file per tuning key under ``$REPRO_TUNE_CACHE`` (or
``~/.cache/repro-tune``).  The key is derived from the *heuristic* plan
signature × the full device assortment (:func:`device_assortment`:
kinds × counts × process count) × jax version
(``repro.tuning.search``), so a second process constructing an
``Executor`` over an identical graph on the same hardware (the serving
pattern) loads the tuned configuration with zero re-measurement — and a
process on DIFFERENT hardware (more devices, another kind, multi-host)
misses instead of inheriting a wrong decision.

Robustness contract:

* files carry ``schema`` versioning — a version mismatch is treated as
  a miss (re-measured under ``tune="auto"``), never a crash;
* a corrupt / truncated / hand-edited-broken file falls back to
  heuristics with a SINGLE ``RuntimeWarning`` per file per process;
* writes are atomic (temp file + ``os.replace``) so a concurrent
  reader never observes a half-written entry;
* an in-process memo makes repeat loads free (no file IO on the second
  ``Executor(tune="auto")`` construction in the same process);
* cross-PROCESS tuning races serialize through a lock file
  (:func:`tuning_lock`): two processes auto-tuning the same key take
  the lock around measure+store, so the second blocks until the first
  persists and then LOADS instead of re-measuring.  The lock is
  advisory and crash-safe — a stale lock older than ``stale_s`` is
  broken (the holder died), and an unlockable directory degrades to
  running unlocked (worst case: duplicated measurement, last atomic
  write wins — exactly the pre-lock behavior).
"""

from __future__ import annotations

import json
import os
import tempfile
import time
import warnings
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Optional

__all__ = ["SCHEMA_VERSION", "LEGACY_SCHEMA_VERSION", "cache_dir",
           "cache_path", "device_assortment", "load", "store", "clear_memo",
           "tuning_lock"]

#: Current on-disk schema.  v3 entries carry the joint tuner's
#: per-segment layout assignments and proposed/pruned/measured counts.
#: (Schema 2 never shipped; the pre-joint coordinate tuner wrote
#: schema 1 under ``repro-tune-v2`` keys.)
SCHEMA_VERSION = 3

#: Schema written by the v2 coordinate-descent tuner.  ``search.py``
#: migration-reads these (``load(key, schema=LEGACY_SCHEMA_VERSION)``)
#: and re-persists feasible decisions under the v3 key without
#: re-measurement.
LEGACY_SCHEMA_VERSION = 1

# in-process memo: key -> validated payload (None entries are not memoized
# so a file written later in the process is still picked up)
_MEMO: dict[str, dict] = {}
# cache files already warned about (the "single warning" contract)
_WARNED: set[str] = set()


def cache_dir() -> Path:
    """The tuning-cache directory: ``$REPRO_TUNE_CACHE`` if set, else
    ``~/.cache/repro-tune``."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-tune"


def cache_path(key: str) -> Path:
    """The JSON file holding the tuned decision for ``key``."""
    return cache_dir() / f"{key}.json"


def device_assortment() -> tuple:
    """The runtime's FULL device complement as a hashable key: sorted
    ``(platform, device_kind, count)`` triples over ``jax.devices()``
    plus the process count.

    Tuning measurements are only transferable between identical
    assortments — a decision measured on 1×cpu must not hit for 8×cpu
    (different sharding, different collectives), nor a single-host
    measurement for a multi-host mesh.  Keying by ``devices()[0]``
    alone conflated all of those; ``tuning_key`` hashes this instead."""
    import jax

    counts: dict[tuple[str, str], int] = {}
    for d in jax.devices():
        k = (d.platform, str(getattr(d, "device_kind", "")))
        counts[k] = counts.get(k, 0) + 1
    try:
        procs = int(jax.process_count())
    except Exception:   # very old jax: single-process by definition
        procs = 1
    return (tuple(sorted((p, kind, n) for (p, kind), n in counts.items())),
            procs)


def _validate(payload: Any, key: str, schema: int = SCHEMA_VERSION) -> dict:
    """Raise ``ValueError`` unless ``payload`` is a well-formed entry for
    ``key`` at schema version ``schema``."""
    if not isinstance(payload, dict):
        raise ValueError("payload is not an object")
    if payload.get("schema") != schema:
        raise ValueError(f"schema {payload.get('schema')!r} != "
                         f"{schema}")
    if payload.get("key") != key:
        raise ValueError("key mismatch")
    for field in ("layouts", "tiles"):
        if not isinstance(payload.get(field), dict):
            raise ValueError(f"missing/invalid {field!r}")
    if not isinstance(payload.get("measurements", []), list):
        raise ValueError("invalid measurements")
    return payload


def load(key: str, schema: int = SCHEMA_VERSION) -> Optional[dict]:
    """The cached payload for ``key``, or None (miss).

    ``schema`` selects which version validates — the default is the
    current one; ``search.py`` passes ``LEGACY_SCHEMA_VERSION`` when
    migration-reading a v2 coordinate-tuner entry.  A corrupt or
    schema-incompatible file warns ONCE per process and reads as a miss
    — the caller falls back to heuristics (``load`` mode) or
    re-measures and overwrites (``auto`` mode).  A legacy-schema read
    that misses stays silent (the old entry simply never existed)."""
    memo = _MEMO.get(key)
    if memo is not None:
        return memo if memo.get("schema") == schema else None
    path = cache_path(key)
    _corrupt_if_scheduled(path)
    try:
        text = path.read_text()
    except FileNotFoundError:
        return None
    except OSError as exc:
        _warn_once(path, f"unreadable ({exc})")
        return None
    try:
        payload = _validate(json.loads(text), key, schema=schema)
    except (ValueError, TypeError) as exc:
        if schema == SCHEMA_VERSION:
            _warn_once(path, str(exc))
        return None
    _MEMO[key] = payload
    return payload


def _warn_once(path: Path, reason: str) -> None:
    s = str(path)
    if s in _WARNED:
        return
    _WARNED.add(s)
    warnings.warn(
        f"repro-tune cache {s} is corrupt or incompatible ({reason}) — "
        f"falling back to heuristic layouts/tiles", RuntimeWarning,
        stacklevel=3)


def store(key: str, payload: dict) -> None:
    """Atomically persist ``payload`` under ``key`` (and memoize it).

    An unwritable cache directory degrades to a warning — tuning still
    applies in-process, it just will not survive it."""
    payload = dict(payload, schema=SCHEMA_VERSION, key=key)
    _MEMO[key] = payload
    path = cache_path(key)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                                   prefix=f".{key}.", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
    except OSError as exc:
        warnings.warn(
            f"repro-tune cache {path} could not be written ({exc}) — "
            f"tuned configuration applies to this process only",
            RuntimeWarning, stacklevel=3)


def clear_memo() -> None:
    """Drop the in-process memo and warning dedup (tests)."""
    _MEMO.clear()
    _WARNED.clear()


def _corrupt_if_scheduled(path: Path) -> None:
    """Chaos hook: a scheduled ``tuning.cache.load`` fault of kind
    ``"corrupt"`` garbles the cache file in place before the read, so
    the EXISTING corrupt-file fallback (warn once, treat as miss) is
    what gets exercised; ``"error"``-kind faults raise here instead."""
    from repro.runtime.faults import current_plan

    plan = current_plan()
    if plan is None:
        return
    fault = plan.trip("tuning.cache.load", detail=str(path))
    if fault is not None and fault.kind == "corrupt" and path.exists():
        path.write_text("{ this is not json —")


# -- cross-process lock --------------------------------------------------------

@contextmanager
def tuning_lock(key: str, timeout_s: float = 120.0, stale_s: float = 600.0,
                poll_s: float = 0.05):
    """Advisory cross-process lock for one tuning key.

    ``O_CREAT | O_EXCL`` on ``<key>.lock`` is the atomic acquire (NFS-
    and POSIX-safe without fcntl); the holder's pid and timestamp go in
    the file for debuggability.  Waiters poll; a lock file older than
    ``stale_s`` is broken (its creator died mid-measure), and a waiter
    that cannot acquire within ``timeout_s`` — or cannot create files
    in the cache dir at all — proceeds UNLOCKED with a warning, because
    duplicated measurement is strictly better than a wedged process
    (the final ``os.replace`` in :func:`store` keeps whichever write
    lands last, both of which are valid measurements)."""
    lock = cache_dir() / f"{key}.lock"
    acquired = False
    deadline = time.monotonic() + timeout_s
    try:
        cache_dir().mkdir(parents=True, exist_ok=True)
    except OSError:
        yield False
        return
    while True:
        try:
            fd = os.open(str(lock), os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            with os.fdopen(fd, "w") as f:
                f.write(f"{os.getpid()} {time.time()}\n")
            acquired = True
            break
        except FileExistsError:
            try:
                age = time.time() - lock.stat().st_mtime
            except OSError:       # holder released between open and stat
                continue
            if age > stale_s:
                try:              # break the stale lock; race-safe: only
                    lock.unlink()  # one unlink succeeds, then both retry
                except OSError:
                    pass
                continue
            if time.monotonic() > deadline:
                warnings.warn(
                    f"repro-tune lock {lock} held for {timeout_s:.0f}s — "
                    f"proceeding unlocked (duplicate measurement)",
                    RuntimeWarning, stacklevel=3)
                break
            time.sleep(poll_s)
        except OSError as exc:
            warnings.warn(
                f"repro-tune lock {lock} could not be created ({exc}) — "
                f"proceeding unlocked", RuntimeWarning, stacklevel=3)
            break
    try:
        yield acquired
    finally:
        if acquired:
            try:
                lock.unlink()
            except OSError:
                pass
