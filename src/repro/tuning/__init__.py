"""repro.tuning — measured autotuner for layout & kernel tiling.

Turns the layout solver's static heuristics and the kernels' fixed tile
defaults into *measured* decisions (HONEI's per-architecture tuned
backends, CrystalGPU's transparent execution-parameter selection):

* :mod:`repro.tuning.search` — the search driver: proposes the JOINT
  (per-key layout × per-kernel tile) candidate space (plus per-segment
  layout refinements), prunes it with the HLO cost model, times the
  surviving candidates as real executions of the plan's region
  executables under a :class:`~repro.tuning.search.TuneBudget`, and
  commits the argmin (``Executor(tune="auto", tune_budget=...)``);
* :mod:`repro.tuning.cache` — the persistent on-disk cache
  (``~/.cache/repro-tune`` or ``$REPRO_TUNE_CACHE``), keyed by plan
  signature × device kind × jax version, so a second process loads
  tuned configs with zero re-measurement;
* :mod:`repro.tuning.tiles` — the per-kernel ``tile_candidates()``
  registry and the ambient tile scope ops wrappers resolve through;
* :mod:`repro.tuning.timing` — the shared first-call/steady-state
  timing harness (re-exported by ``benchmarks/common.py``).

This package's ``__init__`` stays import-light (no ``repro.core``
import): ``core/executor.py`` imports :mod:`tiles` at module load, and
the search driver is loaded lazily on first attribute access.
"""

from . import cache, tiles, timing
from .cache import cache_dir, cache_path, clear_memo, tuning_lock
from .tiles import (active_tiles, record_tile_use, register_tile_kernel,
                    registered_tile_kernels, resolve_tile, tile_candidates,
                    tile_scope)
from .tiles import tile_distance
from .timing import time_fn, time_fn_budget, time_fn_split

__all__ = [
    "cache", "tiles", "timing",
    "cache_dir", "cache_path", "clear_memo", "tuning_lock",
    "active_tiles", "record_tile_use", "register_tile_kernel",
    "registered_tile_kernels", "resolve_tile", "tile_candidates",
    "tile_distance", "tile_scope",
    "time_fn", "time_fn_budget", "time_fn_split",
    # lazy (search imports repro.core):
    "Measurement", "TuneBudget", "TuningDecision", "STATS", "reset_stats",
    "resolve_tuning", "measure_plan", "tuning_key", "legacy_tuning_key",
    "search",
]

_LAZY = {"Measurement", "TuneBudget", "TuningDecision", "STATS",
         "reset_stats", "resolve_tuning", "measure_plan", "tuning_key",
         "legacy_tuning_key", "search"}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        search = importlib.import_module(".search", __name__)
        if name == "search":
            return search
        return getattr(search, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
