"""Loop-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE, regardless
of trip count — useless for scan-over-layers models (verified: a 10-step
scanned matmul reports 1 matmul of FLOPs).  This module re-derives the
three roofline inputs by parsing ``compiled.as_text()``:

* **FLOPs**  — 2*M*N*K for every ``dot`` (batch dims included), found in
  all computations (including fusion bodies), multiplied up by the trip
  count of every enclosing ``while``.
* **bytes**  — per-op surface traffic (result + operands) for ops in
  non-fused computations; fusion ops contribute their boundary bytes only
  (post-fusion traffic); ``dynamic-(update-)slice`` contributes the slice,
  not the sliced buffer (XLA updates in place); bitcast/tuple/gte free.
* **collective bytes** — per-device link traffic with ring-algorithm
  factors: all-reduce 2(n-1)/n, all-gather/reduce-scatter/all-to-all
  (n-1)/n, collective-permute 1; n = replica-group size parsed per op.

Trip counts come from the ``while`` condition computation: jax scans emit
``compare(iter, constant(N)), direction=LT`` — we take that N.

Validated in tests/test_hlo_analysis.py against hand-counted programs.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(f8e4m3fn|f8e5m2|bf16|f16|f32|f64|pred|s4|s8|s16|"
                       r"s32|s64|u4|u8|u16|u32|u64|c64|c128)\[([\d,]*)\]")

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\((.*)$")

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)(?:\.clone)?\s*\((.*?)\)"
                          r"\s*->")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_ZERO_BYTE_OPS = {"bitcast", "tuple", "get-tuple-element", "parameter",
                  "constant", "after-all", "add-dependency", "while",
                  "conditional", "call", "partition-id", "replica-id",
                  "optimization-barrier"}

# ops a TPU-class fusion pass melts into producers/consumers: counted as
# zero HBM traffic in the default "fused" bytes model (the CPU backend
# leaves many of these unfused, which would otherwise overcount ~10x)
_FUSE_FREE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "negate", "abs", "sign", "compare", "select", "and", "or", "xor", "not",
    "convert", "broadcast", "iota", "rsqrt", "sqrt", "cbrt", "power",
    "clamp", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "cosine", "sine", "tan", "atan2", "is-finite", "reduce-precision",
    "bitcast-convert", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "population-count", "count-leading-zeros",
    "real", "imag", "complex", "expm1", "log1p", "logistic", "erf",
    "stochastic-convert", "map", "reverse",
}


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(sig: str) -> list[int]:
    m = _SHAPE_RE.search(sig)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Op:
    name: str
    result_sig: str
    opcode: str
    rest: str            # everything after the opening paren
    operands: list[str] = field(default_factory=list)


@dataclass
class _Computation:
    name: str
    ops: list[_Op] = field(default_factory=list)
    param_sigs: dict = field(default_factory=dict)
    fused: bool = False  # reached via fusion `calls=` (bytes not counted)


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, _Computation] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._mark_fused()
        self._memo_flops: dict[str, float] = {}
        self._memo_bytes: dict[str, float] = {}
        self._memo_coll: dict[str, dict] = {}

    # -- parsing -----------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[_Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and line.endswith("{"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    cur = _Computation(m.group(1))
                    for p in re.finditer(
                            r"([\w.\-]+)\s*:\s*"
                            r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]"
                            r"(?:\{[0-9,]*\})?))",
                            m.group(2)):
                        cur.param_sigs[p.group(1)] = p.group(2)
                    self.comps[cur.name] = cur
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur.name
                continue
            if line.strip() == "}":
                continue
            if cur is None:
                continue
            m = _OP_RE.match(line)
            if m:
                op = _Op(m.group(1), m.group(2), m.group(3), m.group(4))
                op.operands = re.findall(r"%([\w.\-]+)", m.group(4))
                cur.ops.append(op)

    def _mark_fused(self) -> None:
        for comp in self.comps.values():
            for op in comp.ops:
                if op.opcode == "fusion":
                    for callee in re.findall(r"calls=%?([\w.\-]+)", op.rest):
                        if callee in self.comps:
                            self.comps[callee].fused = True
                # reduce/sort/map/scatter appliers: tiny, mark fused so we
                # skip their byte accounting
                for callee in re.findall(r"to_apply=%?([\w.\-]+)", op.rest):
                    if callee in self.comps:
                        self.comps[callee].fused = True

    # -- helpers -----------------------------------------------------------
    def _result_bytes_of(self, comp: _Computation, name: str) -> int:
        if name in comp.param_sigs:
            return _shape_bytes(comp.param_sigs[name])
        for op in comp.ops:
            if op.name == name:
                return _shape_bytes(op.result_sig)
        return 0

    def _result_dims_of(self, comp: _Computation, name: str) -> list[int]:
        if name in comp.param_sigs:
            return _shape_dims(comp.param_sigs[name])
        for op in comp.ops:
            if op.name == name:
                return _shape_dims(op.result_sig)
        return []

    def _trip_count(self, cond_name: str) -> int:
        """jax scans: condition compares the s32 counter against a
        constant with direction=LT; take the largest such constant."""
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts = []
        for op in comp.ops:
            if op.opcode == "constant" and "s32[]" in op.result_sig:
                m = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
                if m:
                    consts.append(int(m.group(1)))
            m = re.match(r"constant\((-?\d+)\)", op.opcode + "(" + op.rest) \
                if False else None
        # also catch inline constant(N) text anywhere in the condition
        if not consts:
            for op in comp.ops:
                for m in re.finditer(r"constant\((\d+)\)", op.rest):
                    consts.append(int(m.group(1)))
        return max(consts) if consts else 1

    def _called(self, op: _Op) -> list[tuple[str, float]]:
        """(callee, multiplier) pairs for control-flow ops."""
        out = []
        if op.opcode == "while":
            body = re.search(r"body=%?([\w.\-]+)", op.rest)
            cond = re.search(r"condition=%?([\w.\-]+)", op.rest)
            trips = self._trip_count(cond.group(1)) if cond else 1
            if body:
                out.append((body.group(1), float(max(trips, 1))))
            if cond:
                out.append((cond.group(1), float(max(trips, 1))))
        elif op.opcode in ("fusion", "call", "map", "reduce", "reduce-window",
                           "scatter", "sort", "select-and-scatter"):
            for callee in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                     op.rest):
                out.append((callee, 1.0))
        elif op.opcode == "conditional":
            for m in re.finditer(r"branch_computations=\{([^}]*)\}", op.rest):
                for c in re.findall(r"%?([\w.\-]+)", m.group(1)):
                    out.append((c, 1.0))  # upper bound: all branches
        return out

    # -- FLOPs ---------------------------------------------------------------
    def _dot_flops(self, comp: _Computation, op: _Op) -> float:
        out_elems = 1
        for d in _shape_dims(op.result_sig):
            out_elems *= d
        lhs = op.operands[0] if op.operands else None
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        if lhs is not None and m:
            dims = self._result_dims_of(comp, lhs)
            for i in [int(x) for x in m.group(1).split(",") if x]:
                if i < len(dims):
                    k *= dims[i]
        return 2.0 * out_elems * k

    def flops(self, comp_name: Optional[str] = None) -> float:
        name = comp_name or self.entry
        if name in self._memo_flops:
            return self._memo_flops[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                total += self._dot_flops(comp, op)
            for callee, mult in self._called(op):
                total += mult * self.flops(callee)
        self._memo_flops[name] = total
        return total

    # -- bytes ---------------------------------------------------------------
    def _op_bytes(self, comp: _Computation, op: _Op) -> float:
        """TPU-fusion-aware HBM traffic model: elementwise chains are free
        (they fuse); data-movement and matmul ops pay result + operands."""
        if op.opcode in _ZERO_BYTE_OPS or op.opcode in _FUSE_FREE_OPS:
            return 0.0
        res = _shape_bytes(op.result_sig)
        if op.opcode == "dynamic-update-slice":
            upd = (self._result_bytes_of(comp, op.operands[1])
                   if len(op.operands) > 1 else 0)
            return 2.0 * upd
        if op.opcode in ("dynamic-slice", "slice", "gather", "pad",
                         "copy", "transpose", "reshape"):
            return 2.0 * res
        if op.opcode in ("reduce", "reduce-window"):
            return res + self._result_bytes_of(comp, op.operands[0]) \
                if op.operands else res
        if op.opcode == "fusion":
            return self._fusion_bytes(comp, op)
        ops_b = sum(self._result_bytes_of(comp, o) for o in op.operands[:8])
        return res + ops_b

    _CAST_ONLY = {"convert", "bitcast", "parameter", "constant", "tuple",
                  "get-tuple-element", "copy-start", "copy-done"}

    def _fusion_bytes(self, comp: _Computation, op: _Op) -> float:
        """Fusion traffic with two TPU-realism corrections:

        * cast-only fusions (convert/bitcast of a whole buffer) are free —
          the CPU backend materializes f32 copies of bf16 buffers that a
          bf16-native TPU never would;
        * fusions containing a dynamic-update-slice are in-place updates:
          they pay for the updated slice (+ sliced reads), not the buffer.
        """
        callee_m = re.search(r"calls=%?([\w.\-]+)", op.rest)
        callee = self.comps.get(callee_m.group(1)) if callee_m else None
        res = _shape_bytes(op.result_sig)
        if callee is None:
            return res + sum(self._result_bytes_of(comp, o)
                             for o in op.operands[:8])
        kinds = {o.opcode for o in callee.ops}
        if kinds <= self._CAST_ONLY:
            return 0.0
        if "dynamic-update-slice" in kinds:
            total = 0.0
            for o in callee.ops:
                if o.opcode == "dynamic-update-slice" and len(o.operands) > 1:
                    total += 2.0 * self._result_bytes_of(callee,
                                                         o.operands[1])
                elif o.opcode in ("dynamic-slice", "slice", "gather", "pad",
                                  "copy", "transpose", "reshape"):
                    total += 2.0 * _shape_bytes(o.result_sig)
            return total
        return res + self._fusion_operand_bytes(comp, op, callee)

    def _fusion_operand_bytes(self, comp: _Computation, op: _Op,
                              callee: Optional[_Computation] = None) -> float:
        """Operand traffic of a fusion: an operand that is only
        (dynamic-)sliced inside the fused computation pays the slice sizes,
        not the full buffer (scan bodies slice stacked params in fusions)."""
        if callee is None:
            callee_m = re.search(r"calls=%?([\w.\-]+)", op.rest)
            callee = self.comps.get(callee_m.group(1)) if callee_m else None
        # fusion operands map positionally to callee params param_0..param_N
        total = 0.0
        for i, operand in enumerate(op.operands):
            full = self._result_bytes_of(comp, operand)
            if callee is None:
                total += full
                continue
            pname_prefix = f"param_{i}"
            consumers = [o for o in callee.ops
                         if any(x == pname_prefix
                                or x.startswith(pname_prefix + ".")
                                for x in o.operands[:1] + o.operands[1:2])]
            if consumers and all(c.opcode in ("dynamic-slice", "slice",
                                              "gather")
                                 for c in consumers):
                total += sum(2.0 * _shape_bytes(c.result_sig)
                             for c in consumers)
            else:
                total += full
        return total

    def bytes_accessed(self, comp_name: Optional[str] = None) -> float:
        name = comp_name or self.entry
        if name in self._memo_bytes:
            return self._memo_bytes[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if not comp.fused:
                total += self._op_bytes(comp, op)
            for callee, mult in self._called(op):
                if op.opcode == "fusion":
                    continue  # fusion internals: boundary already counted
                total += mult * self.bytes_accessed(callee)
        self._memo_bytes[name] = total
        return total

    # -- collectives -----------------------------------------------------------
    def _group_size(self, op: _Op) -> int:
        m = re.search(r"replica_groups=\{\{([\d,]*)\}", op.rest)
        if m:
            return len([x for x in m.group(1).split(",") if x])
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.rest)
        if m:  # iota format [groups, size]
            return int(m.group(2))
        return 2

    def _coll_link_bytes(self, op: _Op) -> float:
        n = max(self._group_size(op), 2)
        size = _shape_bytes(op.result_sig)
        kind = op.opcode.replace("-start", "")
        if kind == "all-reduce":
            return 2.0 * size * (n - 1) / n
        if kind in ("all-gather", "all-to-all"):
            return size * (n - 1) / n
        if kind == "reduce-scatter":
            return size  # result is already the scattered shard; input n x
        if kind == "collective-permute":
            return size
        return 0.0

    def collectives(self, comp_name: Optional[str] = None) -> dict:
        name = comp_name or self.entry
        if name in self._memo_coll:
            return self._memo_coll[name]
        comp = self.comps.get(name)
        out = {k: {"count": 0.0, "link_bytes": 0.0} for k in COLLECTIVES}
        if comp is None:
            return out
        for op in comp.ops:
            kind = op.opcode.replace("-start", "")
            if kind in COLLECTIVES and not op.opcode.endswith("-done"):
                out[kind]["count"] += 1
                out[kind]["link_bytes"] += self._coll_link_bytes(op)
            for callee, mult in self._called(op):
                sub = self.collectives(callee)
                for k in COLLECTIVES:
                    out[k]["count"] += mult * sub[k]["count"]
                    out[k]["link_bytes"] += mult * sub[k]["link_bytes"]
        self._memo_coll[name] = out
        return out

    # -- tagged subtrees --------------------------------------------------------
    def _comp_matches(self, name: str, pattern: str, _seen=None) -> bool:
        if _seen is None:
            _seen = set()
        if name in _seen:
            return False
        _seen.add(name)
        comp = self.comps.get(name)
        if comp is None:
            return False
        rx = re.compile(pattern)
        for op in comp.ops:
            if rx.search(op.rest):
                return True
            for callee, _ in self._called(op):
                if self._comp_matches(callee, pattern, _seen):
                    return True
        return False

    def _has_matching_inner_while(self, name: str, pattern: str) -> bool:
        """Does this computation (transitively) contain a while whose body
        matches the pattern?"""
        comp = self.comps.get(name)
        if comp is None:
            return False
        for op in comp.ops:
            for callee, _ in self._called(op):
                if op.opcode == "while":
                    body = re.search(r"body=%?([\w.\-]+)", op.rest)
                    if body and callee == body.group(1) \
                            and self._comp_matches(callee, pattern):
                        return True
                if self._has_matching_inner_while(callee, pattern):
                    return True
        return False

    def tagged_while_bytes(self, pattern: str) -> float:
        """Total bytes (trip-multiplied) of every INNERMOST ``while``
        subtree whose body matches ``pattern`` (e.g. an einsum label in op
        metadata).  Outer scans that merely contain a matching inner scan
        are not tagged.  Used to attribute the jnp chunked-attention
        scan's HBM traffic so the Pallas-kernel projection can substitute
        it (benchmarks/roofline --flash-credit)."""
        total = 0.0

        def walk(name: str, mult: float, inside: bool) -> None:
            nonlocal total
            comp = self.comps.get(name)
            if comp is None:
                return
            for op in comp.ops:
                if inside and not comp.fused:
                    total += mult * self._op_bytes(comp, op)
                for callee, k in self._called(op):
                    if op.opcode == "fusion" and inside:
                        continue
                    sub_inside = inside
                    if op.opcode == "while" and not inside:
                        body = re.search(r"body=%?([\w.\-]+)", op.rest)
                        if body and callee == body.group(1) \
                                and self._comp_matches(callee, pattern) \
                                and not self._has_matching_inner_while(
                                    callee, pattern):
                            sub_inside = True
                    if op.opcode == "fusion" and not inside:
                        continue
                    walk(callee, mult * k, sub_inside)

        walk(self.entry, 1.0, False)
        return total

    def summary(self) -> dict:
        coll = self.collectives()
        return {
            "flops": self.flops(),
            "bytes": self.bytes_accessed(),
            "collectives": {k: {"count": v["count"],
                                "link_bytes": v["link_bytes"]}
                            for k, v in coll.items()},
            "collective_link_bytes": sum(v["link_bytes"]
                                         for v in coll.values()),
        }


# -- candidate cost ranking (the measured autotuner's pruning stage) -----------
#
# The joint tuner (repro/tuning/search.py) proposes a cross product of
# per-key layouts x per-kernel tiles plus per-segment layout flips — far
# more configurations than it can afford to time.  CostRanker turns the
# HEURISTIC plan's compiled region HLO into a traffic baseline (the true
# post-fusion bytes the program moves) and ranks each candidate by that
# baseline plus an analytic penalty the caller derives from the
# candidate's layout plan (relayout traffic, strided field access).
# Only the top-ranked candidates are ever measured; the rest are pruned.

# analytic per-access penalty factors on a record's storage bytes: a
# layout whose fields are interleaved (AoS) reads each field with stride
# num_components — on vector hardware that wastes a fraction of every
# cache line / VREG load; AoSoA amortizes the stride over its lane tile;
# SoA streams each field contiguously.  These are RANKING weights for
# pruning, not absolute costs — the survivors still get measured.
LAYOUT_PENALTY_FACTORS = {"AOS": 0.5, "AOSOA": 0.125, "SOA": 0.0}


def layout_access_penalty(layout_name: str, storage_bytes: float,
                          num_fields: int = 2) -> float:
    """Analytic strided-access penalty bytes for touching one record
    stored under ``layout_name`` (single-field records pay nothing —
    every layout stores them contiguously)."""
    if num_fields <= 1:
        return 0.0
    return LAYOUT_PENALTY_FACTORS.get(layout_name, 0.0) * storage_bytes


@dataclass(frozen=True)
class CandidateCost:
    """One ranked tuning candidate: the shared HLO base traffic plus the
    candidate's analytic penalty."""

    label: str
    penalty_bytes: float
    predicted_bytes: float

    def describe(self) -> str:
        return (f"{self.label}: predicted {self.predicted_bytes:.3e} B "
                f"(penalty {self.penalty_bytes:.3e} B)")


class CostRanker:
    """Rank joint (layout x tile) tuning candidates.

    Built from the heuristic plan's compiled region HLO texts
    (``Executor.region_hlo`` per device region); :meth:`rank` orders
    candidates by ``base_bytes + penalty_bytes`` ascending, with a
    STABLE sort so the caller controls tie-breaking by pre-ordering its
    entries (the tuner orders ties nearest-to-default-tile first).
    """

    def __init__(self, hlo_texts):
        self.models = [HloCostModel(t) for t in hlo_texts]
        self.base_bytes = float(sum(m.bytes_accessed()
                                    for m in self.models))
        self.base_flops = float(sum(m.flops() for m in self.models))

    def predict(self, penalty_bytes: float) -> float:
        """Predicted traffic of one candidate: the heuristic plan's HLO
        bytes plus the candidate's analytic penalty."""
        return self.base_bytes + float(penalty_bytes)

    def rank(self, entries) -> list[CandidateCost]:
        """``entries`` is an iterable of ``(label, penalty_bytes)``;
        returns :class:`CandidateCost` rows sorted cheapest-first
        (stable: equal predictions keep the caller's order)."""
        costs = [CandidateCost(label, float(p), self.predict(p))
                 for label, p in entries]
        return sorted(costs, key=lambda c: c.predicted_bytes)

    def describe(self) -> str:
        return (f"HLO cost base: {self.base_flops:.3e} flops, "
                f"{self.base_bytes:.3e} bytes over "
                f"{len(self.models)} device region(s)")


def normalize_cost_analysis(cost) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returns a per-device *list* of dicts (one per addressable
    device); newer JAX returns the dict directly.  Always hand back a
    dict (element 0 of a list — the numbers are identical across devices
    for SPMD programs), and ``{}`` for None/empty."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def analyze_hlo(hlo_text: str) -> dict:
    return HloCostModel(hlo_text).summary()
