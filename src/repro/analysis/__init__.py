"""Compiled-HLO analysis: loop-aware FLOPs / bytes / collective census."""

from .hlo import (CandidateCost, CostRanker, HloCostModel, analyze_hlo,
                  layout_access_penalty, normalize_cost_analysis)

__all__ = ["CandidateCost", "CostRanker", "HloCostModel", "analyze_hlo",
           "layout_access_penalty", "normalize_cost_analysis"]
