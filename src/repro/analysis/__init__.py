"""Compiled-HLO analysis: loop-aware FLOPs / bytes / collective census."""

from .hlo import HloCostModel, analyze_hlo, normalize_cost_analysis

__all__ = ["HloCostModel", "analyze_hlo", "normalize_cost_analysis"]
